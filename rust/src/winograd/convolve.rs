//! The region-wise multi-channel pipeline (the paper's §2, Figure 2),
//! executed **region-blocked** over a reusable workspace arena:
//!
//! 1. **Input transform** — walk the regions of the NHWC input, transform
//!    each `th×tw` tile into the Winograd domain four channels at a time and
//!    *scatter* the results into the `x²` GEMM A-matrices `[R×C]`.
//! 2. **GEMM** — `x²` batched products with the pre-transformed weight
//!    B-matrices `[C×M]` (channel summation of Hadamard products becomes the
//!    GEMM inner dimension).
//! 3. **Output transform** — *gather* each region's `x²` values back out of
//!    the C-matrices `[R×M]`, apply the inverse transform and write the
//!    spatial output tile.
//!
//! The GEMM shape is `[R×C]·[C×M]` (not `[M×C]·[C×R]`) following §2.1.3:
//! under NHWC the scattered channel vectors land contiguously in the rows of
//! an `R×C` matrix (plain `STR` stores, no `ST4` interleaving).
//!
//! ## Region blocking
//!
//! Rather than materialising the whole feature map in the Winograd domain
//! (an `x²·R·C` A buffer plus an `x²·R·M` C buffer per layer — the
//! cache-hostile working-set blow-up that lets FFT/ im2row catch up on
//! large layers), the pipeline processes regions in **blocks**: scatter →
//! `x²` GEMMs → gather run per block of `Rb` regions, where `Rb` is chosen
//! so the A block, C block and one packed-B panel together fit an L2 budget
//! ([`DEFAULT_L2_BUDGET`], overridable per convolution with
//! [`WinogradConvolution::with_block_budget`] or globally with the
//! `WINOCONV_L2_BUDGET` env var). The block scratch comes from a caller-
//! provided [`Workspace`] arena, so steady-state inference allocates
//! nothing inside stages 1–3.

use super::{fast, transform::transform_tile_lanes, transform::transform_tile_scalar};
use super::{WinogradPlan, WinogradVariant};
use crate::gemm::{pack::packed_b_panel_bytes, BatchedGemm, Blocking, PackedB};
use crate::parallel::ThreadPool;
use crate::simd::F32x4;
use crate::tensor::Tensor;
use crate::util::ceil_div;
use crate::workspace::Workspace;
use crate::{bail_shape, bail_unsupported, Result};

/// Maximum input-tile edge among shipped variants (F(4,7) ⇒ 10).
const MAX_T: usize = 10;

/// Default per-block workspace budget: the A block, C block and one
/// packed-B panel of a region block must fit in this many bytes. Sized for
/// the ~512 KiB–1 MiB L2 of the mobile cores the paper targets.
pub const DEFAULT_L2_BUDGET: usize = 512 * 1024;

/// The block budget in effect for new convolutions: `WINOCONV_L2_BUDGET`
/// (bytes) when set and parseable, else [`DEFAULT_L2_BUDGET`].
pub fn default_block_budget() -> usize {
    std::env::var("WINOCONV_L2_BUDGET")
        .ok()
        .and_then(|s| s.parse::<usize>().ok())
        .filter(|&b| b > 0)
        .unwrap_or(DEFAULT_L2_BUDGET)
}

/// A Winograd convolution with pre-transformed weights, reusable across
/// inputs (weights are transformed once per layer, as in the paper — filter
/// transform cost is off the inference path).
#[derive(Debug, Clone)]
pub struct WinogradConvolution {
    plan: WinogradPlan,
    cin: usize,
    cout: usize,
    pad: (usize, usize),
    /// Per-block workspace budget in bytes (see [`DEFAULT_L2_BUDGET`]).
    block_budget: usize,
    /// Transformed weights `[tile][C][M]` pre-packed into GEMM panel
    /// layout, one per tile position (EXPERIMENTS.md §Perf step 2: packing
    /// B per call dominated skinny-R layers; now it happens once here).
    u_packed: Vec<PackedB>,
}

impl WinogradConvolution {
    /// Transform `weights` (`[M, KH, KW, C]`) for `variant` with symmetric
    /// spatial padding `pad = (ph, pw)`.
    pub fn new(variant: WinogradVariant, weights: &Tensor, pad: (usize, usize)) -> Result<Self> {
        if weights.rank() != 4 {
            bail_shape!("weights must be [M, KH, KW, C], got {:?}", weights.shape());
        }
        let (m_out, kh, kw, cin) = (
            weights.shape()[0],
            weights.shape()[1],
            weights.shape()[2],
            weights.shape()[3],
        );
        let plan = WinogradPlan::new(variant);
        plan.check_kernel(kh, kw)?;
        let (th, tw) = (plan.h.t, plan.w.t);
        let tiles = th * tw;

        // U[(i,j)][c][m] = (G_h · g · G_wᵀ)[i][j] for filter (m, c).
        let mut u = vec![0.0f32; tiles * cin * m_out];
        let mut g_tile = vec![0.0f32; kh * kw];
        let mut out = vec![0.0f32; tiles];
        let mut tmp = vec![0.0f32; th * kw];
        for m in 0..m_out {
            for c in 0..cin {
                for a in 0..kh {
                    for b in 0..kw {
                        g_tile[a * kw + b] = weights.at4(m, a, b, c);
                    }
                }
                transform_tile_scalar(&plan.h.g, &plan.w.g, &g_tile, &mut out, &mut tmp);
                for t in 0..tiles {
                    u[t * cin * m_out + c * m_out + m] = out[t];
                }
            }
        }

        let u_packed = (0..tiles)
            .map(|t| PackedB::pack(&u[t * cin * m_out..], m_out, cin, m_out))
            .collect();

        Ok(WinogradConvolution {
            plan,
            cin,
            cout: m_out,
            pad,
            block_budget: default_block_budget(),
            u_packed,
        })
    }

    /// Builder: override the per-block workspace budget in bytes. A budget
    /// smaller than one region's footprint degenerates to one region per
    /// block; `usize::MAX` disables blocking (one block spans the layer).
    pub fn with_block_budget(mut self, bytes: usize) -> Self {
        self.block_budget = bytes.max(1);
        self
    }

    /// The per-block workspace budget in bytes.
    pub fn block_budget(&self) -> usize {
        self.block_budget
    }

    /// The plan in use.
    pub fn plan(&self) -> &WinogradPlan {
        &self.plan
    }

    /// Output channels.
    pub fn out_channels(&self) -> usize {
        self.cout
    }

    /// Output spatial size for an `[N, H, W, C]` input (stride is always 1 —
    /// the Winograd/Cook-Toom formulation requires it; strided layers fall
    /// back to im2row in the selector).
    pub fn output_hw(&self, h: usize, w: usize) -> Result<(usize, usize)> {
        let (kh, kw) = self.plan.variant.kernel();
        let (ph, pw) = self.pad;
        if h + 2 * ph < kh || w + 2 * pw < kw {
            bail_shape!("input {h}x{w} (pad {ph},{pw}) smaller than filter {kh}x{kw}");
        }
        Ok((h + 2 * ph - kh + 1, w + 2 * pw - kw + 1))
    }

    /// Regions per block under the budget: the largest `Rb` such that the
    /// A block (`x²·Rb·C`), C block (`x²·Rb·M`) and one packed-B panel fit
    /// in [`block_budget`](Self::block_budget) bytes, aligned down to whole
    /// tile rows when possible and clamped to `[1, regions]`.
    fn block_regions(&self, regions: usize, tiles_w: usize) -> usize {
        let tiles = self.plan.variant.gemm_count();
        let per_region = tiles * (self.cin + self.cout) * std::mem::size_of::<f32>();
        let panel = packed_b_panel_bytes(Blocking::default().kc.min(self.cin.max(1)));
        let avail = self.block_budget.saturating_sub(panel);
        let mut rb = (avail / per_region).max(1);
        if rb >= tiles_w {
            rb -= rb % tiles_w;
        }
        rb.clamp(1, regions.max(1))
    }

    /// Regions per block for an `[n, h, w, C]` input (see `block_regions`).
    pub fn regions_per_block(&self, n: usize, h: usize, w: usize) -> Result<usize> {
        let (oh, ow) = self.output_hw(h, w)?;
        let (mh, mw) = self.plan.variant.out_tile();
        let (tiles_h, tiles_w) = (ceil_div(oh, mh), ceil_div(ow, mw));
        Ok(self.block_regions(n * tiles_h * tiles_w, tiles_w))
    }

    /// Per-block workspace bytes (A block + C block) for an `[n, h, w, C]`
    /// input — the number that must sit under the configured L2 budget.
    pub fn block_workspace_bytes(&self, n: usize, h: usize, w: usize) -> Result<usize> {
        Ok(self.workspace_elems_for(n, h, w)? * std::mem::size_of::<f32>())
    }

    /// Workspace elements ([`f32`]s) one inference over an `[n, h, w, C]`
    /// input borrows from the arena — used to pre-size per-thread arenas.
    pub fn workspace_elems_for(&self, n: usize, h: usize, w: usize) -> Result<usize> {
        let rb = self.regions_per_block(n, h, w)?;
        let tiles = self.plan.variant.gemm_count();
        Ok(tiles * rb * (self.cin + self.cout))
    }

    /// Run the three-stage pipeline. `pool` parallelises regions and GEMMs.
    ///
    /// Allocates a throwaway [`Workspace`]; hot loops should hold one and
    /// call [`run_fused_with`](Self::run_fused_with) instead.
    pub fn run(&self, input: &Tensor, pool: Option<&ThreadPool>) -> Result<Tensor> {
        self.run_fused(input, pool, None, false)
    }

    /// [`run`](Self::run) with a fused epilogue: per-output-channel bias and
    /// optional ReLU applied inside the output-transform stage, while the
    /// tile is still in registers — saving one full pass over the output
    /// tensor (EXPERIMENTS.md §Perf step 6).
    pub fn run_fused(
        &self,
        input: &Tensor,
        pool: Option<&ThreadPool>,
        bias: Option<&[f32]>,
        relu: bool,
    ) -> Result<Tensor> {
        let mut ws = Workspace::new();
        self.run_fused_with(input, pool, bias, relu, &mut ws)
    }

    /// The region-blocked pipeline over a caller-owned arena: blocks of
    /// `Rb` regions flow through scatter → `x²` batched GEMMs → gather, and
    /// the only heap traffic is the arena's one-time growth (none at all
    /// once `ws` is at size — the zero-steady-state-allocation property the
    /// arena-reuse tests pin).
    pub fn run_fused_with(
        &self,
        input: &Tensor,
        pool: Option<&ThreadPool>,
        bias: Option<&[f32]>,
        relu: bool,
        ws: &mut Workspace,
    ) -> Result<Tensor> {
        if input.rank() != 4 {
            bail_shape!("input must be [N, H, W, C], got {:?}", input.shape());
        }
        let (n, h, w, c) = (
            input.shape()[0],
            input.shape()[1],
            input.shape()[2],
            input.shape()[3],
        );
        if c != self.cin {
            bail_shape!("input has {c} channels, weights expect {}", self.cin);
        }
        if let Some(b) = bias {
            if b.len() != self.cout {
                bail_shape!("bias length {} vs {} output channels", b.len(), self.cout);
            }
        }
        let (oh, ow) = self.output_hw(h, w)?;
        let v = self.plan.variant;
        let (mh, mw) = v.out_tile();
        let (th, tw) = v.in_tile();
        let tiles = th * tw;
        let (tiles_h, tiles_w) = (ceil_div(oh, mh), ceil_div(ow, mw));
        let regions = n * tiles_h * tiles_w;
        let m_total = self.cout;

        // Stage 0: pad so every tile is in-bounds (right/bottom rounded up
        // to the tile grid).
        let (ph, pw) = self.pad;
        let need_h = tiles_h * mh + th - mh; // = tiles_h*mh + kh - 1
        let need_w = tiles_w * mw + tw - mw;
        let padded = input.pad_spatial(ph, need_h - h - ph, pw, need_w - w - pw);

        let mut output = Tensor::zeros(&[n, oh, ow, m_total]);

        // One A/C block pair for the whole layer, reused across blocks.
        let rb = self.block_regions(regions, tiles_w);
        let (a_blk, c_blk) = ws.split2(tiles * rb * c, tiles * rb * m_total);

        for r0 in (0..regions).step_by(rb) {
            let bm = (regions - r0).min(rb);

            // Stage 1: input transform + scatter into A `[tile][bm][C]`.
            {
                let a_addr = a_blk.as_mut_ptr() as usize;
                let transform_region = |li: usize| {
                    let region = r0 + li;
                    let b = region / (tiles_h * tiles_w);
                    let rem = region % (tiles_h * tiles_w);
                    let (ty, tx) = (rem / tiles_w, rem % tiles_w);
                    let (y0, x0) = (ty * mh, tx * mw);
                    let mut d = [F32x4::zero(); MAX_T * MAX_T];
                    let mut out = [F32x4::zero(); MAX_T * MAX_T];
                    let mut tmp = [F32x4::zero(); MAX_T * MAX_T];
                    for cg in (0..c).step_by(4) {
                        let lanes = (c - cg).min(4);
                        // Gather the th×tw tile for this 4-channel group.
                        for i in 0..th {
                            for j in 0..tw {
                                let px = padded.pixel(b, y0 + i, x0 + j);
                                d[i * tw + j] = if lanes == 4 {
                                    F32x4::load(&px[cg..cg + 4])
                                } else {
                                    F32x4::load_partial(&px[cg..])
                                };
                            }
                        }
                        // Transform (fast path when available).
                        match v {
                            WinogradVariant::F2x2_3x3 => fast::input_transform_4x4(&d, &mut out),
                            // F(2,5) shares F(4,3)'s interpolation points, hence
                            // the identical 6×6 Bᵀ (pinned by a fast.rs test).
                            WinogradVariant::F4x4_3x3 | WinogradVariant::F2x2_5x5 => {
                                fast::input_transform_6x6(&d, &mut out)
                            }
                            _ => transform_tile_lanes(
                                &self.plan.h.bt,
                                &self.plan.w.bt,
                                &d[..th * tw],
                                &mut out,
                                &mut tmp,
                            ),
                        }
                        // Scatter: A[t][li][cg..] — contiguous channel run in
                        // the row of an R×C matrix (§2.1.3 unstructured stores).
                        for t in 0..tiles {
                            // SAFETY: each block-local region li writes its
                            // own row slice only.
                            let dst: &mut [f32] = unsafe {
                                std::slice::from_raw_parts_mut(
                                    (a_addr as *mut f32).add(t * bm * c + li * c + cg),
                                    lanes,
                                )
                            };
                            out[t].store_partial(dst, lanes);
                        }
                    }
                };
                match pool {
                    Some(pool) => pool.parallel_for(bm, transform_region),
                    None => (0..bm).for_each(transform_region),
                }
            }

            // Stage 2: x² batched GEMMs — [bm×C]·[C×M] per tile position.
            let bgd = BatchedGemm {
                batch: tiles,
                m: bm,
                k: c,
                n: m_total,
            };
            bgd.run_prepacked(pool, &a_blk[..], &self.u_packed, &mut c_blk[..]);

            // Stage 3: gather + output transform.
            {
                let out_addr = output.data_mut().as_mut_ptr() as usize;
                let c_ref: &[f32] = &c_blk[..];
                let inverse_region = |li: usize| {
                    let region = r0 + li;
                    let b = region / (tiles_h * tiles_w);
                    let rem = region % (tiles_h * tiles_w);
                    let (ty, tx) = (rem / tiles_w, rem % tiles_w);
                    let (y0, x0) = (ty * mh, tx * mw);
                    let valid_h = (oh - y0).min(mh);
                    let valid_w = (ow - x0).min(mw);
                    let mut t_in = [F32x4::zero(); MAX_T * MAX_T];
                    let mut y_out = [F32x4::zero(); MAX_T * MAX_T];
                    let mut tmp = [F32x4::zero(); MAX_T * MAX_T];
                    for mg in (0..m_total).step_by(4) {
                        let lanes = (m_total - mg).min(4);
                        // Gather the x² values of this region/channel-group.
                        for t in 0..tiles {
                            let src = &c_ref[t * bm * m_total + li * m_total + mg..];
                            t_in[t] = if lanes == 4 {
                                F32x4::load(&src[..4])
                            } else {
                                F32x4::load_partial(&src[..lanes])
                            };
                        }
                        match v {
                            WinogradVariant::F2x2_3x3 => {
                                fast::output_transform_4x4(&t_in, &mut y_out)
                            }
                            WinogradVariant::F4x4_3x3 => {
                                fast::output_transform_6x6(&t_in, &mut y_out)
                            }
                            WinogradVariant::F2x2_5x5 => {
                                fast::output_transform_6x6_to_2x2(&t_in, &mut y_out)
                            }
                            _ => transform_tile_lanes(
                                &self.plan.h.at,
                                &self.plan.w.at,
                                &t_in[..tiles],
                                &mut y_out,
                                &mut tmp,
                            ),
                        }
                        // Fused epilogue: bias + ReLU while the tile is hot.
                        if bias.is_some() || relu {
                            let bv = match bias {
                                Some(b) => F32x4::load_partial(&b[mg..mg + lanes]),
                                None => F32x4::zero(),
                            };
                            for yv in y_out[..mh * mw].iter_mut() {
                                let mut t = *yv + bv;
                                if relu {
                                    t = t.max(F32x4::zero());
                                }
                                *yv = t;
                            }
                        }
                        // Write the valid part of the mh×mw output tile.
                        for i in 0..valid_h {
                            for j in 0..valid_w {
                                let off = (((b * oh + y0 + i) * ow) + x0 + j) * m_total + mg;
                                // SAFETY: output tiles are disjoint across regions.
                                let dst: &mut [f32] = unsafe {
                                    std::slice::from_raw_parts_mut(
                                        (out_addr as *mut f32).add(off),
                                        lanes,
                                    )
                                };
                                y_out[i * mw + j].store_partial(dst, lanes);
                            }
                        }
                    }
                };
                match pool {
                    Some(pool) => pool.parallel_for(bm, inverse_region),
                    None => (0..bm).for_each(inverse_region),
                }
            }
        }

        Ok(output)
    }

    /// Size of the **unblocked** Winograd-domain working set in bytes for an
    /// input `[n, h, w, c]` (full A + C matrices) — the number the paper's
    /// memory budget discussion cares about, and what region blocking caps
    /// at [`block_workspace_bytes`](Self::block_workspace_bytes).
    pub fn workspace_bytes(&self, n: usize, h: usize, w: usize) -> Result<usize> {
        let (oh, ow) = self.output_hw(h, w)?;
        let (mh, mw) = self.plan.variant.out_tile();
        let regions = n * ceil_div(oh, mh) * ceil_div(ow, mw);
        let tiles = self.plan.variant.gemm_count();
        Ok((tiles * regions * (self.cin + self.cout)) * std::mem::size_of::<f32>())
    }
}

/// One-shot convenience: transform weights and run a single input.
pub fn winograd_conv2d(
    variant: WinogradVariant,
    input: &Tensor,
    weights: &Tensor,
    pad: (usize, usize),
    pool: Option<&ThreadPool>,
) -> Result<Tensor> {
    if input.rank() == 4 && weights.rank() == 4 {
        // Winograd is a stride-1 algorithm; reject anything else upstream.
    } else {
        bail_unsupported!("winograd_conv2d expects rank-4 input and weights");
    }
    WinogradConvolution::new(variant, weights, pad)?.run(input, pool)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::conv::direct::direct_conv2d;

    fn check_variant(
        v: WinogradVariant,
        n: usize,
        h: usize,
        w: usize,
        c: usize,
        m: usize,
        pad: (usize, usize),
    ) {
        let (kh, kw) = v.kernel();
        let input = Tensor::randn(&[n, h, w, c], 42 + h as u64);
        let weights = Tensor::randn(&[m, kh, kw, c], 7 + c as u64);
        let got = winograd_conv2d(v, &input, &weights, pad, None).unwrap();
        let want = direct_conv2d(&input, &weights, (1, 1), pad).unwrap();
        assert_eq!(got.shape(), want.shape(), "{v}");
        assert!(
            got.allclose(&want, 5e-4),
            "{v} mismatch: rel err {}",
            crate::util::rel_error(got.data(), want.data())
        );
    }

    #[test]
    fn f2x2_3x3_matches_direct() {
        check_variant(WinogradVariant::F2x2_3x3, 1, 8, 8, 4, 8, (0, 0));
        check_variant(WinogradVariant::F2x2_3x3, 2, 9, 11, 3, 5, (1, 1));
    }

    #[test]
    fn f4x4_3x3_matches_direct() {
        check_variant(WinogradVariant::F4x4_3x3, 1, 12, 12, 8, 16, (1, 1));
        check_variant(WinogradVariant::F4x4_3x3, 1, 7, 13, 5, 3, (0, 0));
    }

    #[test]
    fn f6x6_3x3_matches_direct() {
        check_variant(WinogradVariant::F6x6_3x3, 1, 14, 14, 4, 4, (1, 1));
    }

    #[test]
    fn f2x2_5x5_matches_direct() {
        check_variant(WinogradVariant::F2x2_5x5, 1, 12, 12, 4, 6, (2, 2));
        check_variant(WinogradVariant::F2x2_5x5, 1, 9, 9, 3, 4, (0, 0));
    }

    #[test]
    fn f4x4_5x5_matches_direct() {
        check_variant(WinogradVariant::F4x4_5x5, 1, 13, 13, 3, 4, (2, 2));
    }

    #[test]
    fn one_d_variants_match_direct() {
        check_variant(WinogradVariant::F2_1x7, 1, 6, 17, 4, 6, (0, 3));
        check_variant(WinogradVariant::F2_7x1, 1, 17, 6, 4, 6, (3, 0));
        check_variant(WinogradVariant::F4_1x7, 1, 6, 19, 4, 6, (0, 3));
        check_variant(WinogradVariant::F4_7x1, 1, 19, 6, 4, 6, (3, 0));
        check_variant(WinogradVariant::F4_1x3, 1, 5, 15, 3, 4, (0, 1));
        check_variant(WinogradVariant::F4_3x1, 1, 15, 5, 3, 4, (1, 0));
    }

    #[test]
    fn ragged_output_tiles() {
        // Output sizes that don't divide the tile: exercises gather clipping.
        check_variant(WinogradVariant::F4x4_3x3, 1, 9, 10, 3, 5, (1, 1));
        check_variant(WinogradVariant::F2x2_3x3, 1, 6, 5, 2, 3, (0, 0));
    }

    #[test]
    fn parallel_matches_serial() {
        let pool = ThreadPool::new(4);
        let v = WinogradVariant::F4x4_3x3;
        let input = Tensor::randn(&[1, 20, 20, 16], 1);
        let weights = Tensor::randn(&[32, 3, 3, 16], 2);
        let serial = winograd_conv2d(v, &input, &weights, (1, 1), None).unwrap();
        let parallel = winograd_conv2d(v, &input, &weights, (1, 1), Some(&pool)).unwrap();
        assert!(parallel.allclose(&serial, 1e-5));
    }

    #[test]
    fn reusable_transformed_weights() {
        let weights = Tensor::randn(&[8, 3, 3, 4], 3);
        let conv = WinogradConvolution::new(WinogradVariant::F2x2_3x3, &weights, (1, 1)).unwrap();
        for seed in [10, 20] {
            let input = Tensor::randn(&[1, 8, 8, 4], seed);
            let got = conv.run(&input, None).unwrap();
            let want = direct_conv2d(&input, &weights, (1, 1), (1, 1)).unwrap();
            assert!(got.allclose(&want, 5e-4));
        }
    }

    /// The tentpole equivalence: forcing many small region blocks (budget 1
    /// byte ⇒ one region per block) must reproduce the unblocked result
    /// (budget `usize::MAX` ⇒ one block) bit-for-bit-close, for every
    /// shipped variant, on odd shapes with partial tiles, serial and
    /// pooled.
    #[test]
    fn blocked_matches_unblocked_all_variants() {
        let pool = ThreadPool::new(3);
        for v in WinogradVariant::ALL {
            let (kh, kw) = v.kernel();
            // Odd extents ⇒ ragged tile grids on both axes for 2-D variants.
            let (h, w) = (kh + 9, kw + 11);
            let input = Tensor::randn(&[2, h, w, 5], 3);
            let weights = Tensor::randn(&[7, kh, kw, 5], 4);
            let unblocked = WinogradConvolution::new(v, &weights, (0, 0))
                .unwrap()
                .with_block_budget(usize::MAX);
            let blocked = WinogradConvolution::new(v, &weights, (0, 0))
                .unwrap()
                .with_block_budget(1);
            let want = unblocked.run(&input, None).unwrap();
            let got = blocked.run(&input, None).unwrap();
            assert_eq!(got.shape(), want.shape(), "{v}");
            assert!(got.allclose(&want, 1e-5), "{v}: blocked != unblocked (serial)");
            let got_par = blocked.run(&input, Some(&pool)).unwrap();
            assert!(got_par.allclose(&want, 1e-5), "{v}: blocked != unblocked (pool)");
            let direct = direct_conv2d(&input, &weights, (1, 1), (0, 0)).unwrap();
            assert!(got.allclose(&direct, 2e-3), "{v}: blocked != direct");
        }
    }

    /// A mid-sized budget that yields several multi-region blocks (the
    /// realistic configuration, between the two extremes above).
    #[test]
    fn blocked_mid_budget_matches_direct() {
        let weights = Tensor::randn(&[16, 3, 3, 8], 5);
        let conv = WinogradConvolution::new(WinogradVariant::F4x4_3x3, &weights, (1, 1))
            .unwrap()
            .with_block_budget(36 * (8 + 16) * 4 * 3 + packed_b_panel_bytes(8));
        let rb = conv.regions_per_block(1, 18, 18).unwrap();
        assert!(rb >= 2, "budget should allow several regions, got {rb}");
        let regions = 5 * 5; // ceil(18/4)^2
        assert!(rb < regions, "budget should force multiple blocks, got {rb}");
        let input = Tensor::randn(&[1, 18, 18, 8], 6);
        let got = conv.run(&input, None).unwrap();
        let want = direct_conv2d(&input, &weights, (1, 1), (1, 1)).unwrap();
        assert!(got.allclose(&want, 5e-4));
    }

    /// Repeated runs over one arena must not re-grow it, and a pre-sized
    /// arena must never grow at all.
    #[test]
    fn workspace_reused_across_runs() {
        let weights = Tensor::randn(&[16, 3, 3, 8], 7);
        let conv = WinogradConvolution::new(WinogradVariant::F4x4_3x3, &weights, (1, 1)).unwrap();
        let mut ws = Workspace::new();
        for seed in 0..3 {
            let input = Tensor::randn(&[1, 12, 12, 8], seed + 10);
            let _ = conv.run_fused_with(&input, None, None, false, &mut ws).unwrap();
        }
        assert_eq!(ws.grow_count(), 1, "one growth on first use, then reuse");

        let elems = conv.workspace_elems_for(1, 12, 12).unwrap();
        let mut presized = Workspace::with_capacity(elems);
        let input = Tensor::randn(&[1, 12, 12, 8], 99);
        let _ = conv
            .run_fused_with(&input, None, None, false, &mut presized)
            .unwrap();
        assert_eq!(presized.grow_count(), 0, "pre-sized arena must not grow");
        assert_eq!(presized.high_water_elems(), elems, "sizing formula is exact");
    }

    #[test]
    fn block_sizing_respects_budget() {
        let weights = Tensor::randn(&[32, 3, 3, 16], 8);
        for budget in [64 * 1024, 256 * 1024, DEFAULT_L2_BUDGET] {
            let conv = WinogradConvolution::new(WinogradVariant::F4x4_3x3, &weights, (1, 1))
                .unwrap()
                .with_block_budget(budget);
            let per_block = conv.block_workspace_bytes(1, 56, 56).unwrap();
            let rb = conv.regions_per_block(1, 56, 56).unwrap();
            // Either the block fits the budget, or it degenerated to the
            // 1-region minimum (budget below one region's footprint).
            assert!(
                per_block + packed_b_panel_bytes(16) <= budget || rb == 1,
                "budget {budget}: per-block {per_block} B, rb {rb}"
            );
            assert!(rb >= 1);
        }
    }

    #[test]
    fn rejects_wrong_kernel_shape() {
        let weights = Tensor::randn(&[8, 5, 5, 4], 3);
        assert!(WinogradConvolution::new(WinogradVariant::F2x2_3x3, &weights, (0, 0)).is_err());
    }

    #[test]
    fn rejects_channel_mismatch() {
        let weights = Tensor::randn(&[8, 3, 3, 4], 3);
        let conv = WinogradConvolution::new(WinogradVariant::F2x2_3x3, &weights, (0, 0)).unwrap();
        let input = Tensor::randn(&[1, 8, 8, 5], 1);
        assert!(conv.run(&input, None).is_err());
    }

    #[test]
    fn workspace_accounting() {
        let weights = Tensor::randn(&[16, 3, 3, 8], 3);
        let conv = WinogradConvolution::new(WinogradVariant::F2x2_3x3, &weights, (1, 1)).unwrap();
        // 8×8 input, pad 1 ⇒ 8×8 output ⇒ 4×4 regions = 16; 16 tiles.
        let ws = conv.workspace_bytes(1, 8, 8).unwrap();
        assert_eq!(ws, 16 * 16 * (8 + 16) * 4);
        // The blocked working set never exceeds the unblocked one.
        assert!(conv.block_workspace_bytes(1, 8, 8).unwrap() <= ws);
    }
}
