//! The region-wise multi-channel pipeline (the paper's §2, Figure 2),
//! executed **region-blocked** over a reusable workspace arena with the
//! transforms fused into the GEMM's pack and epilogue steps:
//!
//! 1. **Transform-as-pack** — walk the regions of the NHWC input, transform
//!    each `th×tw` tile into the Winograd domain four channels at a time
//!    and scatter the results *directly into `MR`-strided packed-A panel
//!    layout* ([`crate::gemm::pack::packed_a_index`]), one packed image per
//!    GEMM tile position. The packed panels are the values' first and only
//!    materialisation: there is no row-major A staging buffer and no
//!    separate `pack_a` copy pass inside the GEMM.
//! 2. **GEMM + gather-as-epilogue** — `x²` batched products against the
//!    pre-packed weight B-matrices run per `MR`-region row panel
//!    ([`BatchedGemm::run_packed_fused`]); each finished
//!    `[x²]×MR×NR` hot cube is handed, still L1-hot, to a
//!    [`crate::gemm::Epilogue`] that applies the inverse transform, fused
//!    bias + ReLU, and writes the spatial output tile. The Winograd-domain
//!    C matrices are **never materialised**, and conv outputs are written
//!    exactly once.
//!
//! This is the paper's §2.2 interleaving argument made structural: its
//! BLASFEO-class kernels fuse packing and transforms so data moves through
//! the cache hierarchy once, which is what keeps region-wise Winograd
//! ahead of im2row/FFT on mobile-class memory systems.
//!
//! The GEMM shape is `[R×C]·[C×M]` (not `[M×C]·[C×R]`) following §2.1.3:
//! under NHWC the channel vectors of one region form one logical row of an
//! `R×C` matrix (in packed layout, the row's cells sit `MR` apart).
//!
//! ## Region blocking
//!
//! Rather than transforming the whole feature map at once, regions flow
//! through the two fused stages in **blocks** of `Rb` regions, where `Rb`
//! is chosen so the packed-A block (padded to whole `MR` row panels), one
//! packed-B panel and the per-thread hot cube together fit an L2 budget
//! ([`DEFAULT_L2_BUDGET`], overridable per convolution with
//! [`WinogradConvolution::with_block_budget`] or globally with the
//! `WINOCONV_L2_BUDGET` env var, read once per process). The block scratch
//! **and** the padded-input staging buffer come from a caller-provided
//! [`Workspace`] arena, and the write-into entry point
//! ([`WinogradConvolution::run_fused_into`]) lands the conv output in a
//! caller-provided slice — with a warm arena a whole inference through this
//! path performs zero heap allocation. The allocating
//! [`WinogradConvolution::run_fused_with`] survives as a thin wrapper
//! (and test oracle) over it.
//!
//! The pre-fusion three-stage pipeline (scatter → staged GEMMs → gather)
//! is kept as [`WinogradConvolution::run_staged_with`]: it is the ablation
//! baseline (`ablation_amortization` E6) and the oracle the fused path is
//! property-tested against.

use super::transform::{transform_and_pack, transform_tile_lanes, transform_tile_scalar};
use super::{fast, WinogradPlan, WinogradVariant};
use crate::gemm::pack::{packed_b_panel_bytes, PackedAWriter};
use crate::gemm::{Activation, BatchedGemm, Blocking, Epilogue, PackedB, MR, NR};
use crate::parallel::ThreadPool;
use crate::simd::F32x4;
use crate::tensor::{Tensor, TensorView};
use crate::util::ceil_div;
use crate::workspace::Workspace;
use crate::{bail_shape, bail_unsupported, Result};
use std::sync::OnceLock;

/// Maximum input-tile edge among shipped variants (F(4,7) ⇒ 10).
const MAX_T: usize = 10;

/// Default per-block workspace budget: the packed-A block, one packed-B
/// panel and the per-thread hot cube of a region block must fit in this
/// many bytes. Sized for the ~512 KiB–1 MiB L2 of the mobile cores the
/// paper targets.
pub const DEFAULT_L2_BUDGET: usize = 512 * 1024;

/// The block budget in effect for new convolutions: `WINOCONV_L2_BUDGET`
/// (bytes) when set and parseable, else [`DEFAULT_L2_BUDGET`].
///
/// The environment is consulted **once per process** (cached in a
/// `OnceLock`) — `WinogradConvolution` construction sits on the
/// model-prepare path, and re-parsing the environment per layer was
/// measurable noise on many-layer models. Use
/// [`WinogradConvolution::with_block_budget`] for per-convolution control.
pub fn default_block_budget() -> usize {
    static BUDGET: OnceLock<usize> = OnceLock::new();
    *BUDGET.get_or_init(|| {
        std::env::var("WINOCONV_L2_BUDGET")
            .ok()
            .and_then(|s| s.parse::<usize>().ok())
            .filter(|&b| b > 0)
            .unwrap_or(DEFAULT_L2_BUDGET)
    })
}

/// A Winograd convolution with pre-transformed weights, reusable across
/// inputs (weights are transformed once per layer, as in the paper — filter
/// transform cost is off the inference path).
#[derive(Debug, Clone)]
pub struct WinogradConvolution {
    plan: WinogradPlan,
    cin: usize,
    cout: usize,
    pad: (usize, usize),
    /// Per-block workspace budget in bytes (see [`DEFAULT_L2_BUDGET`]).
    block_budget: usize,
    /// Transformed weights `[tile][C][M]` pre-packed into GEMM panel
    /// layout, one per tile position (EXPERIMENTS.md §Perf step 2: packing
    /// B per call dominated skinny-R layers; now it happens once here).
    u_packed: Vec<PackedB>,
}

/// Resolved per-run geometry shared by the fused and staged pipelines.
struct RunGeometry {
    oh: usize,
    ow: usize,
    tiles_h: usize,
    tiles_w: usize,
    regions: usize,
    /// Extents the input must be padded to so every tile is in-bounds
    /// (symmetric user padding plus right/bottom round-up to the tile
    /// grid). When these equal the input extents no staging copy is made.
    need_h: usize,
    need_w: usize,
}

impl WinogradConvolution {
    /// Transform `weights` (`[M, KH, KW, C]`) for `variant` with symmetric
    /// spatial padding `pad = (ph, pw)`.
    pub fn new(variant: WinogradVariant, weights: &Tensor, pad: (usize, usize)) -> Result<Self> {
        if weights.rank() != 4 {
            bail_shape!("weights must be [M, KH, KW, C], got {:?}", weights.shape());
        }
        let (m_out, kh, kw, cin) = (
            weights.shape()[0],
            weights.shape()[1],
            weights.shape()[2],
            weights.shape()[3],
        );
        let plan = WinogradPlan::new(variant);
        plan.check_kernel(kh, kw)?;
        let (th, tw) = (plan.h.t, plan.w.t);
        let tiles = th * tw;

        // U[(i,j)][c][m] = (G_h · g · G_wᵀ)[i][j] for filter (m, c).
        let mut u = vec![0.0f32; tiles * cin * m_out];
        let mut g_tile = vec![0.0f32; kh * kw];
        let mut out = vec![0.0f32; tiles];
        let mut tmp = vec![0.0f32; th * kw];
        for m in 0..m_out {
            for c in 0..cin {
                for a in 0..kh {
                    for b in 0..kw {
                        g_tile[a * kw + b] = weights.at4(m, a, b, c);
                    }
                }
                transform_tile_scalar(&plan.h.g, &plan.w.g, &g_tile, &mut out, &mut tmp);
                for t in 0..tiles {
                    u[t * cin * m_out + c * m_out + m] = out[t];
                }
            }
        }

        let u_packed = (0..tiles)
            .map(|t| PackedB::pack(&u[t * cin * m_out..], m_out, cin, m_out))
            .collect();

        Ok(WinogradConvolution {
            plan,
            cin,
            cout: m_out,
            pad,
            block_budget: default_block_budget(),
            u_packed,
        })
    }

    /// Builder: override the per-block workspace budget in bytes. A budget
    /// smaller than one `MR`-panel's footprint degenerates to one region
    /// per block; `usize::MAX` disables blocking (one block spans the
    /// layer).
    pub fn with_block_budget(mut self, bytes: usize) -> Self {
        self.block_budget = bytes.max(1);
        self
    }

    /// The per-block workspace budget in bytes.
    pub fn block_budget(&self) -> usize {
        self.block_budget
    }

    /// The plan in use.
    pub fn plan(&self) -> &WinogradPlan {
        &self.plan
    }

    /// Output channels.
    pub fn out_channels(&self) -> usize {
        self.cout
    }

    /// Output spatial size for an `[N, H, W, C]` input (stride is always 1 —
    /// the Winograd/Cook-Toom formulation requires it; strided layers fall
    /// back to im2row in the selector).
    pub fn output_hw(&self, h: usize, w: usize) -> Result<(usize, usize)> {
        let (kh, kw) = self.plan.variant.kernel();
        let (ph, pw) = self.pad;
        if h + 2 * ph < kh || w + 2 * pw < kw {
            bail_shape!("input {h}x{w} (pad {ph},{pw}) smaller than filter {kh}x{kw}");
        }
        Ok((h + 2 * ph - kh + 1, w + 2 * pw - kw + 1))
    }

    /// Regions per block under the budget.
    ///
    /// Fused (`staged == false`): the largest `Rb` whose packed-A block
    /// (`x² · ceil(Rb/MR)·MR · C`, padded to whole `MR` row panels), one
    /// packed-B panel and the per-thread `x²·MR·NR` hot cube fit in
    /// [`block_budget`](Self::block_budget) bytes. `Rb` is drawn from whole
    /// `MR` panels so the padding itself stays inside the budget, then
    /// aligned down to whole tile rows when possible.
    ///
    /// Staged: the pre-fusion accounting — A block (`x²·Rb·C`) plus C block
    /// (`x²·Rb·M`) plus one packed-B panel.
    fn block_regions(&self, regions: usize, tiles_w: usize, staged: bool) -> usize {
        let tiles = self.plan.variant.gemm_count();
        let f32s = std::mem::size_of::<f32>();
        let panel = packed_b_panel_bytes(Blocking::default().kc.min(self.cin.max(1)));
        let mut rb = if staged {
            let per_region = tiles * (self.cin + self.cout) * f32s;
            let avail = self.block_budget.saturating_sub(panel);
            (avail / per_region.max(1)).max(1)
        } else {
            let hot = tiles * MR * NR * f32s;
            let per_row = tiles * self.cin * f32s;
            let avail = self.block_budget.saturating_sub(panel + hot);
            let max_rows = avail / per_row.max(1);
            if max_rows >= MR {
                (max_rows / MR) * MR
            } else {
                1
            }
        };
        if rb >= tiles_w {
            rb -= rb % tiles_w;
        }
        rb.clamp(1, regions.max(1))
    }

    /// Regions per block for an `[n, h, w, C]` input on the fused pipeline
    /// (see `block_regions`).
    pub fn regions_per_block(&self, n: usize, h: usize, w: usize) -> Result<usize> {
        let g = self.geometry(n, h, w)?;
        Ok(self.block_regions(g.regions, g.tiles_w, false))
    }

    /// Per-block workspace bytes (the packed-A block) for an `[n, h, w, C]`
    /// input — the number that must sit under the configured L2 budget
    /// together with one packed-B panel and the hot cube. Padded-input
    /// staging is deliberately excluded: it is layer-wide input data, not
    /// part of the blocked GEMM working set the budget bounds.
    pub fn block_workspace_bytes(&self, n: usize, h: usize, w: usize) -> Result<usize> {
        Ok(self.packed_a_elems_for(n, h, w)? * std::mem::size_of::<f32>())
    }

    /// Packed-A block elements: `x² · ceil(Rb/MR)·MR · C`.
    fn packed_a_elems_for(&self, n: usize, h: usize, w: usize) -> Result<usize> {
        let rb = self.regions_per_block(n, h, w)?;
        let tiles = self.plan.variant.gemm_count();
        Ok(tiles * rb.div_ceil(MR) * MR * self.cin)
    }

    /// Elements of workspace-owned padded-input staging one inference over
    /// an `[n, h, w, C]` input borrows — `n·need_h·need_w·C` when the layer
    /// pads (user padding or tile-grid round-up), 0 when the input already
    /// sits on the tile grid and no copy is staged at all.
    pub fn staging_elems_for(&self, n: usize, h: usize, w: usize) -> Result<usize> {
        let g = self.geometry(n, h, w)?;
        if g.need_h == h && g.need_w == w {
            Ok(0)
        } else {
            Ok(n * g.need_h * g.need_w * self.cin)
        }
    }

    /// Workspace elements ([`f32`]s) one **fused** inference over an
    /// `[n, h, w, C]` input borrows from the arena — used to pre-size
    /// per-thread arenas. Two disjoint borrows: the padded-input staging
    /// buffer ([`staging_elems_for`](Self::staging_elems_for)) and the
    /// packed-A block (`x² · ceil(Rb/MR)·MR · C`). C blocks no longer
    /// exist on the fused path.
    pub fn workspace_elems_for(&self, n: usize, h: usize, w: usize) -> Result<usize> {
        Ok(self.staging_elems_for(n, h, w)? + self.packed_a_elems_for(n, h, w)?)
    }

    /// Workspace elements one **staged** inference borrows (A block + C
    /// block) — the pre-fusion accounting, kept for the E6 ablation.
    pub fn staged_workspace_elems_for(&self, n: usize, h: usize, w: usize) -> Result<usize> {
        let (oh, ow) = self.output_hw(h, w)?;
        let (mh, mw) = self.plan.variant.out_tile();
        let (tiles_h, tiles_w) = (ceil_div(oh, mh), ceil_div(ow, mw));
        let rb = self.block_regions(n * tiles_h * tiles_w, tiles_w, true);
        let tiles = self.plan.variant.gemm_count();
        Ok(tiles * rb * (self.cin + self.cout))
    }

    /// Resolve the per-run geometry (incl. the stage-0 padded extents)
    /// shared by the fused and staged pipelines.
    fn geometry(&self, n: usize, h: usize, w: usize) -> Result<RunGeometry> {
        let (oh, ow) = self.output_hw(h, w)?;
        let (mh, mw) = self.plan.variant.out_tile();
        let (th, tw) = self.plan.variant.in_tile();
        let (tiles_h, tiles_w) = (ceil_div(oh, mh), ceil_div(ow, mw));
        let need_h = tiles_h * mh + th - mh; // = tiles_h*mh + kh - 1
        let need_w = tiles_w * mw + tw - mw;
        Ok(RunGeometry {
            oh,
            ow,
            tiles_h,
            tiles_w,
            regions: n * tiles_h * tiles_w,
            need_h,
            need_w,
        })
    }

    /// Validate an input view's rank/channels and an optional bias length.
    fn check_input(&self, input: &TensorView, bias: Option<&[f32]>) -> Result<()> {
        if input.rank() != 4 {
            bail_shape!("input must be [N, H, W, C], got {:?}", input.shape());
        }
        if input.shape()[3] != self.cin {
            bail_shape!(
                "input has {} channels, weights expect {}",
                input.shape()[3],
                self.cin
            );
        }
        if let Some(b) = bias {
            if b.len() != self.cout {
                bail_shape!("bias length {} vs {} output channels", b.len(), self.cout);
            }
        }
        Ok(())
    }

    /// Stage the padded input into `staging` (workspace-owned memory) when
    /// the geometry requires it, else pass the input view straight through.
    /// `pshape` must outlive the returned view and hold
    /// `[n, need_h, need_w, c]`.
    fn staged_input<'a>(
        &self,
        input: &TensorView<'a>,
        g: &RunGeometry,
        pshape: &'a [usize; 4],
        staging: &'a mut [f32],
    ) -> Result<TensorView<'a>> {
        let (h, w) = (input.shape()[1], input.shape()[2]);
        if g.need_h == h && g.need_w == w {
            return Ok(*input);
        }
        let (ph, pw) = self.pad;
        input.pad_spatial_into(ph, g.need_h - h - ph, pw, g.need_w - w - pw, staging);
        TensorView::new(pshape, staging)
    }

    /// Run the fused two-stage pipeline. `pool` parallelises regions and
    /// GEMM row panels.
    ///
    /// Allocates a throwaway [`Workspace`]; hot loops should hold one and
    /// call [`run_fused_with`](Self::run_fused_with) instead.
    pub fn run(&self, input: &Tensor, pool: Option<&ThreadPool>) -> Result<Tensor> {
        self.run_fused(input, pool, None, Activation::None)
    }

    /// [`run`](Self::run) with per-output-channel bias and optional ReLU
    /// fused into the gather epilogue — applied while the output tile is
    /// still in registers, so conv outputs are written exactly once.
    pub fn run_fused(
        &self,
        input: &Tensor,
        pool: Option<&ThreadPool>,
        bias: Option<&[f32]>,
        act: Activation,
    ) -> Result<Tensor> {
        let mut ws = Workspace::new();
        self.run_fused_with(input, pool, bias, act, &mut ws)
    }

    /// The fused region-blocked pipeline over a caller-owned arena,
    /// allocating the output tensor. Thin wrapper over
    /// [`run_fused_into`](Self::run_fused_into) — kept as the allocating
    /// oracle the write-into path is property-tested against.
    pub fn run_fused_with(
        &self,
        input: &Tensor,
        pool: Option<&ThreadPool>,
        bias: Option<&[f32]>,
        act: Activation,
        ws: &mut Workspace,
    ) -> Result<Tensor> {
        let view = input.view();
        self.check_input(&view, bias)?;
        let (n, h, w) = (input.shape()[0], input.shape()[1], input.shape()[2]);
        let (oh, ow) = self.output_hw(h, w)?;
        let mut output = Tensor::zeros(&[n, oh, ow, self.cout]);
        self.run_fused_into(&view, pool, bias, act, ws, output.data_mut())?;
        Ok(output)
    }

    /// The fused region-blocked write-into pipeline: blocks of `Rb` regions
    /// flow through transform-as-pack → batched GEMM with
    /// gather-as-epilogue, the padded input is staged into workspace-owned
    /// memory (no copy at all when the input already sits on the tile
    /// grid), and the conv output lands in the caller-provided `out` slice
    /// (`n·oh·ow·M` elements, fully overwritten — dirty arena memory is
    /// fine). With a warm arena this path performs **zero heap
    /// allocation** — the property the planned executor
    /// ([`crate::nn::PreparedModel`]) builds on.
    pub fn run_fused_into(
        &self,
        input: &TensorView,
        pool: Option<&ThreadPool>,
        bias: Option<&[f32]>,
        act: Activation,
        ws: &mut Workspace,
        out: &mut [f32],
    ) -> Result<()> {
        self.check_input(input, bias)?;
        let (n, h, w) = (input.shape()[0], input.shape()[1], input.shape()[2]);
        let g = self.geometry(n, h, w)?;
        let (mh, mw) = self.plan.variant.out_tile();
        let (th, tw) = self.plan.variant.in_tile();
        let tiles = th * tw;
        let (c, m_total) = (self.cin, self.cout);
        if out.len() != n * g.oh * g.ow * m_total {
            bail_shape!(
                "output slice has {} elems, layer writes {}",
                out.len(),
                n * g.oh * g.ow * m_total
            );
        }
        let out_addr = out.as_mut_ptr() as usize;
        // Stage tracing: transform/GEMM nanoseconds accumulate across the
        // region blocks, recorded as two synthetic-interval spans after the
        // sweep (one relaxed load when disabled).
        let tr = crate::trace::enabled();
        let span_t0 = if tr { crate::trace::now_ns() } else { 0 };
        let mut transform_ns = 0u64;
        let mut gemm_ns = 0u64;

        // One staging buffer + packed-A block for the whole layer, reused
        // across blocks (two disjoint arena borrows, zero heap traffic).
        let rb = self.block_regions(g.regions, g.tiles_w, false);
        let staging_elems = self.staging_elems_for(n, h, w)?;
        let (staging, a_blk) =
            ws.split2(staging_elems, tiles * rb.div_ceil(MR) * MR * c);
        let pshape = [n, g.need_h, g.need_w, c];
        let padded = self.staged_input(input, &g, &pshape, staging)?;
        // `bm` takes at most two values (rb, then the last remainder), so
        // the dead rows of a short last panel are zeroed at most twice per
        // run — not per block.
        let mut zeroed_for_bm = None;

        for r0 in (0..g.regions).step_by(rb) {
            let bm = (g.regions - r0).min(rb);
            let panels = bm.div_ceil(MR);
            let tile_stride = panels * MR * c;

            // Stage 1: transform-as-pack. Dead rows of a short last panel
            // must multiply as zero in the micro-kernel.
            if bm % MR != 0 && zeroed_for_bm != Some(bm) {
                for t in 0..tiles {
                    PackedAWriter::new(&mut a_blk[t * tile_stride..(t + 1) * tile_stride], bm, c)
                        .zero_pad_rows();
                }
                zeroed_for_bm = Some(bm);
            }
            {
                let stage_t = if tr { crate::trace::now_ns() } else { 0 };
                let a_addr = a_blk.as_mut_ptr() as usize;
                let a_len = tiles * tile_stride;
                let padded_in = &padded;
                let transform_region = |li: usize| {
                    let region = r0 + li;
                    let b = region / (g.tiles_h * g.tiles_w);
                    let rem = region % (g.tiles_h * g.tiles_w);
                    let (ty, tx) = (rem / g.tiles_w, rem % g.tiles_w);
                    let (y0, x0) = (ty * mh, tx * mw);
                    let mut d = [F32x4::zero(); MAX_T * MAX_T];
                    let mut out = [F32x4::zero(); MAX_T * MAX_T];
                    let mut tmp = [F32x4::zero(); MAX_T * MAX_T];
                    for cg in (0..c).step_by(4) {
                        let lanes = (c - cg).min(4);
                        // Gather the th×tw tile for this 4-channel group.
                        for i in 0..th {
                            for j in 0..tw {
                                let px = padded_in.pixel(b, y0 + i, x0 + j);
                                d[i * tw + j] = if lanes == 4 {
                                    F32x4::load(&px[cg..cg + 4])
                                } else {
                                    F32x4::load_partial(&px[cg..])
                                };
                            }
                        }
                        // Each block-local region li writes only its own
                        // logical row's packed cells (the scatter contract
                        // transform_and_pack documents); rows are disjoint
                        // across parallel regions.
                        transform_and_pack(
                            &self.plan,
                            &d[..th * tw],
                            &mut out,
                            &mut tmp,
                            a_addr,
                            a_len,
                            tile_stride,
                            c,
                            li,
                            cg,
                            lanes,
                        );
                    }
                };
                match pool {
                    Some(pool) => pool.parallel_for(bm, transform_region),
                    None => (0..bm).for_each(transform_region),
                }
                if tr {
                    transform_ns += crate::trace::now_ns().saturating_sub(stage_t);
                }
            }

            // Stage 2: x² batched GEMMs over the packed panels; the gather
            // (inverse transform + bias/ReLU + output store) runs as the
            // epilogue on each L1-hot [x²]×MR×NR cube.
            let bgd = BatchedGemm {
                batch: tiles,
                m: bm,
                k: c,
                n: m_total,
            };
            let gather = GatherEpilogue {
                conv: self,
                out_addr,
                r0,
                tiles_h: g.tiles_h,
                tiles_w: g.tiles_w,
                oh: g.oh,
                ow: g.ow,
                m_total,
                bias,
                act,
            };
            let stage_t = if tr { crate::trace::now_ns() } else { 0 };
            bgd.run_packed_fused(pool, &a_blk[..tiles * tile_stride], &self.u_packed, &gather);
            if tr {
                gemm_ns += crate::trace::now_ns().saturating_sub(stage_t);
            }
        }
        if tr {
            use crate::trace::{AlgoCode, Stage};
            crate::trace::record_stage_at(Stage::Transform, AlgoCode::Winograd, span_t0, transform_ns);
            crate::trace::record_stage_at(
                Stage::Gemm,
                AlgoCode::Winograd,
                span_t0 + transform_ns,
                gemm_ns,
            );
        }

        Ok(())
    }

    /// Allocating twin of
    /// [`run_fused_batched_into`](Self::run_fused_batched_into) — the
    /// oracle its batched-vs-sequential property tests compare against.
    #[allow(clippy::too_many_arguments)]
    pub fn run_fused_batched_with(
        &self,
        batch: &Tensor,
        nb: usize,
        pool: Option<&ThreadPool>,
        bias: Option<&[f32]>,
        act: Activation,
        ws: &mut Workspace,
    ) -> Result<Tensor> {
        if batch.rank() != 4 {
            bail_shape!("batch must be [NB, H, W, C], got {:?}", batch.shape());
        }
        let (h, w) = (batch.shape()[1], batch.shape()[2]);
        let (oh, ow) = self.output_hw(h, w)?;
        let mut out = Tensor::zeros(&[batch.shape()[0], oh, ow, self.cout]);
        self.run_fused_batched_into(&batch.view(), nb, pool, bias, act, ws, out.data_mut())?;
        Ok(out)
    }

    /// Batched write-into entry point: `nb` frames gathered contiguously as
    /// one `[nb, H, W, C]` view execute in a single fused pass. The
    /// prepare-time Winograd-domain weight panels (`u_packed`) are
    /// batch-invariant, so the region-blocked sweep sees one packed-B
    /// traversal per layer while the region count — and with it the
    /// packed-A side — scales `nb`×. Per-region transforms and each output
    /// row's k-accumulation are independent of how many regions share the
    /// sweep, so the result is **bit-identical** to running the frames one
    /// at a time. Allocation-free with a warm arena
    /// (statcheck-registered).
    #[allow(clippy::too_many_arguments)]
    pub fn run_fused_batched_into(
        &self,
        batch: &TensorView,
        nb: usize,
        pool: Option<&ThreadPool>,
        bias: Option<&[f32]>,
        act: Activation,
        ws: &mut Workspace,
        out: &mut [f32],
    ) -> Result<()> {
        crate::conv::check_batch_dim(batch, nb)?;
        self.run_fused_into(batch, pool, bias, act, ws, out)
    }

    /// The pre-fusion three-stage pipeline (scatter → staged `x²` GEMMs →
    /// gather) with a throwaway arena — the E6 ablation baseline.
    pub fn run_staged(&self, input: &Tensor, pool: Option<&ThreadPool>) -> Result<Tensor> {
        let mut ws = Workspace::new();
        self.run_staged_with(input, pool, None, Activation::None, &mut ws)
    }

    /// The pre-fusion three-stage pipeline over a caller-owned arena: the
    /// input transform scatters into a row-major A block, `pack_a` repacks
    /// it inside the GEMM, the Winograd-domain C block is materialised,
    /// and a separate gather pass reads it back. Kept as the ablation
    /// baseline (`ablation_amortization` E6) and as the oracle the fused
    /// path is property-tested against — each extra memory pass here is
    /// exactly what [`run_fused_with`](Self::run_fused_with) deletes.
    pub fn run_staged_with(
        &self,
        input: &Tensor,
        pool: Option<&ThreadPool>,
        bias: Option<&[f32]>,
        act: Activation,
        ws: &mut Workspace,
    ) -> Result<Tensor> {
        self.check_input(&input.view(), bias)?;
        let (n, h, w) = (input.shape()[0], input.shape()[1], input.shape()[2]);
        let g = self.geometry(n, h, w)?;
        let v = self.plan.variant;
        let (mh, mw) = v.out_tile();
        let (th, tw) = v.in_tile();
        let tiles = th * tw;
        let (c, m_total) = (self.cin, self.cout);
        // The pre-fusion baseline keeps its allocating padded copy — the
        // cost the write-into path's workspace staging removes.
        let (ph, pw) = self.pad;
        let padded = input.pad_spatial(ph, g.need_h - h - ph, pw, g.need_w - w - pw);

        let mut output = Tensor::zeros(&[n, g.oh, g.ow, m_total]);

        // One A/C block pair for the whole layer, reused across blocks.
        let rb = self.block_regions(g.regions, g.tiles_w, true);
        let (a_blk, c_blk) = ws.split2(tiles * rb * c, tiles * rb * m_total);

        for r0 in (0..g.regions).step_by(rb) {
            let bm = (g.regions - r0).min(rb);

            // Stage 1: input transform + scatter into A `[tile][bm][C]`.
            {
                let a_addr = a_blk.as_mut_ptr() as usize;
                let padded_in = &padded;
                let transform_region = |li: usize| {
                    let region = r0 + li;
                    let b = region / (g.tiles_h * g.tiles_w);
                    let rem = region % (g.tiles_h * g.tiles_w);
                    let (ty, tx) = (rem / g.tiles_w, rem % g.tiles_w);
                    let (y0, x0) = (ty * mh, tx * mw);
                    let mut d = [F32x4::zero(); MAX_T * MAX_T];
                    let mut out = [F32x4::zero(); MAX_T * MAX_T];
                    let mut tmp = [F32x4::zero(); MAX_T * MAX_T];
                    for cg in (0..c).step_by(4) {
                        let lanes = (c - cg).min(4);
                        for i in 0..th {
                            for j in 0..tw {
                                let px = padded_in.pixel(b, y0 + i, x0 + j);
                                d[i * tw + j] = if lanes == 4 {
                                    F32x4::load(&px[cg..cg + 4])
                                } else {
                                    F32x4::load_partial(&px[cg..])
                                };
                            }
                        }
                        match v {
                            WinogradVariant::F2x2_3x3 => fast::input_transform_4x4(&d, &mut out),
                            // F(2,5) shares F(4,3)'s interpolation points, hence
                            // the identical 6×6 Bᵀ (pinned by a fast.rs test).
                            WinogradVariant::F4x4_3x3 | WinogradVariant::F2x2_5x5 => {
                                fast::input_transform_6x6(&d, &mut out)
                            }
                            _ => transform_tile_lanes(
                                &self.plan.h.bt,
                                &self.plan.w.bt,
                                &d[..th * tw],
                                &mut out,
                                &mut tmp,
                            ),
                        }
                        // Scatter: A[t][li][cg..] — contiguous channel run in
                        // the row of an R×C matrix (§2.1.3 unstructured stores).
                        for t in 0..tiles {
                            // SAFETY: each block-local region li writes its
                            // own row slice only.
                            let dst: &mut [f32] = unsafe {
                                std::slice::from_raw_parts_mut(
                                    (a_addr as *mut f32).add(t * bm * c + li * c + cg),
                                    lanes,
                                )
                            };
                            out[t].store_partial(dst, lanes);
                        }
                    }
                };
                match pool {
                    Some(pool) => pool.parallel_for(bm, transform_region),
                    None => (0..bm).for_each(transform_region),
                }
            }

            // Stage 2: x² batched GEMMs — [bm×C]·[C×M] per tile position.
            let bgd = BatchedGemm {
                batch: tiles,
                m: bm,
                k: c,
                n: m_total,
            };
            bgd.run_prepacked(pool, &a_blk[..], &self.u_packed, &mut c_blk[..]);

            // Stage 3: gather + output transform (a separate pass over the
            // materialised C block — the cost the fused pipeline removes).
            {
                let out_addr = output.data_mut().as_mut_ptr() as usize;
                let c_ref: &[f32] = &c_blk[..];
                let inverse_region = |li: usize| {
                    let region = r0 + li;
                    let b = region / (g.tiles_h * g.tiles_w);
                    let rem = region % (g.tiles_h * g.tiles_w);
                    let (ty, tx) = (rem / g.tiles_w, rem % g.tiles_w);
                    let (y0, x0) = (ty * mh, tx * mw);
                    let valid_h = (g.oh - y0).min(mh);
                    let valid_w = (g.ow - x0).min(mw);
                    let mut t_in = [F32x4::zero(); MAX_T * MAX_T];
                    let mut y_out = [F32x4::zero(); MAX_T * MAX_T];
                    let mut tmp = [F32x4::zero(); MAX_T * MAX_T];
                    for mg in (0..m_total).step_by(4) {
                        let lanes = (m_total - mg).min(4);
                        // Gather the x² values of this region/channel-group.
                        for t in 0..tiles {
                            let src = &c_ref[t * bm * m_total + li * m_total + mg..];
                            t_in[t] = if lanes == 4 {
                                F32x4::load(&src[..4])
                            } else {
                                F32x4::load_partial(&src[..lanes])
                            };
                        }
                        inverse_transform_dispatch(&self.plan, &t_in, &mut y_out, &mut tmp);
                        // Fused epilogue: bias + activation while the tile
                        // is hot.
                        if bias.is_some() || !act.is_none() {
                            let bv = match bias {
                                Some(b) => F32x4::load_partial(&b[mg..mg + lanes]),
                                None => F32x4::zero(),
                            };
                            for yv in y_out[..mh * mw].iter_mut() {
                                *yv = act.apply_vec(*yv + bv);
                            }
                        }
                        // Write the valid part of the mh×mw output tile.
                        for i in 0..valid_h {
                            for j in 0..valid_w {
                                let off =
                                    (((b * g.oh + y0 + i) * g.ow) + x0 + j) * m_total + mg;
                                // SAFETY: output tiles are disjoint across regions.
                                let dst: &mut [f32] = unsafe {
                                    std::slice::from_raw_parts_mut(
                                        (out_addr as *mut f32).add(off),
                                        lanes,
                                    )
                                };
                                y_out[i * mw + j].store_partial(dst, lanes);
                            }
                        }
                    }
                };
                match pool {
                    Some(pool) => pool.parallel_for(bm, inverse_region),
                    None => (0..bm).for_each(inverse_region),
                }
            }
        }

        Ok(output)
    }

    /// Size of the **unblocked, staged** Winograd-domain working set in
    /// bytes for an input `[n, h, w, c]` (full A + C matrices) — the number
    /// the paper's memory budget discussion cares about, and what region
    /// blocking plus fusion cap at [`block_workspace_bytes`](Self::block_workspace_bytes).
    pub fn workspace_bytes(&self, n: usize, h: usize, w: usize) -> Result<usize> {
        let (oh, ow) = self.output_hw(h, w)?;
        let (mh, mw) = self.plan.variant.out_tile();
        let regions = n * ceil_div(oh, mh) * ceil_div(ow, mw);
        let tiles = self.plan.variant.gemm_count();
        Ok((tiles * regions * (self.cin + self.cout)) * std::mem::size_of::<f32>())
    }
}

/// Inverse-transform one region's `x²` Winograd-domain lanes into the
/// spatial output tile, dispatching to the hand-unrolled kernels for the
/// hottest variants.
#[inline]
fn inverse_transform_dispatch(
    plan: &WinogradPlan,
    t_in: &[F32x4],
    y_out: &mut [F32x4],
    tmp: &mut [F32x4],
) {
    let tiles = plan.h.t * plan.w.t;
    match plan.variant {
        WinogradVariant::F2x2_3x3 => fast::output_transform_4x4(t_in, y_out),
        WinogradVariant::F4x4_3x3 => fast::output_transform_6x6(t_in, y_out),
        WinogradVariant::F2x2_5x5 => fast::output_transform_6x6_to_2x2(t_in, y_out),
        _ => transform_tile_lanes(&plan.h.at, &plan.w.at, &t_in[..tiles], y_out, tmp),
    }
}

/// Stage 3 as a GEMM epilogue: inverse transform + fused bias/ReLU + output
/// store, fired by [`BatchedGemm::run_packed_fused`] once per finished
/// `[x²]×MR×NR` hot cube (the cube convention documented there) while it is
/// still L1-hot — the Winograd-domain C matrices never exist in memory.
struct GatherEpilogue<'a> {
    conv: &'a WinogradConvolution,
    /// Raw base of the output tensor (written through disjoint windows).
    out_addr: usize,
    /// First global region of the current block.
    r0: usize,
    tiles_h: usize,
    tiles_w: usize,
    oh: usize,
    ow: usize,
    m_total: usize,
    bias: Option<&'a [f32]>,
    act: Activation,
}

impl Epilogue for GatherEpilogue<'_> {
    fn micro_tile(
        &self,
        c: &mut [f32],
        ldc: usize,
        row0: usize,
        col0: usize,
        rows: usize,
        cols: usize,
    ) {
        let plan = &self.conv.plan;
        let tiles = plan.h.t * plan.w.t;
        let (mh, mw) = plan.variant.out_tile();
        let mut t_in = [F32x4::zero(); MAX_T * MAX_T];
        let mut y_out = [F32x4::zero(); MAX_T * MAX_T];
        let mut tmp = [F32x4::zero(); MAX_T * MAX_T];
        for r in 0..rows {
            let region = self.r0 + row0 + r;
            let b = region / (self.tiles_h * self.tiles_w);
            let rem = region % (self.tiles_h * self.tiles_w);
            let (ty, tx) = (rem / self.tiles_w, rem % self.tiles_w);
            let (y0, x0) = (ty * mh, tx * mw);
            let valid_h = (self.oh - y0).min(mh);
            let valid_w = (self.ow - x0).min(mw);
            for mg in (0..cols).step_by(4) {
                let lanes = (cols - mg).min(4);
                let m_abs = col0 + mg;
                // Gather this region/channel-group across the x² tiles of
                // the hot cube (tile t's micro-tile at c[t·MR·ldc ..]).
                for (t, ti) in t_in[..tiles].iter_mut().enumerate() {
                    let src = &c[t * MR * ldc + r * ldc + mg..];
                    *ti = if lanes == 4 {
                        F32x4::load(&src[..4])
                    } else {
                        F32x4::load_partial(&src[..lanes])
                    };
                }
                inverse_transform_dispatch(plan, &t_in, &mut y_out, &mut tmp);
                // Fused bias + activation while the tile is in registers.
                if self.bias.is_some() || !self.act.is_none() {
                    let bv = match self.bias {
                        Some(bb) => F32x4::load_partial(&bb[m_abs..m_abs + lanes]),
                        None => F32x4::zero(),
                    };
                    for yv in y_out[..mh * mw].iter_mut() {
                        *yv = self.act.apply_vec(*yv + bv);
                    }
                }
                // Write the valid part of the mh×mw output tile.
                for i in 0..valid_h {
                    for j in 0..valid_w {
                        let off =
                            (((b * self.oh + y0 + i) * self.ow) + x0 + j) * self.m_total + m_abs;
                        // SAFETY: regions are disjoint across row panels
                        // (the fused driver's parallel axis) and channel
                        // ranges disjoint across column panels within one
                        // task, so every output element is written by
                        // exactly one epilogue invocation.
                        let dst: &mut [f32] = unsafe {
                            std::slice::from_raw_parts_mut(
                                (self.out_addr as *mut f32).add(off),
                                lanes,
                            )
                        };
                        y_out[i * mw + j].store_partial(dst, lanes);
                    }
                }
            }
        }
    }
}

/// One-shot convenience: transform weights and run a single input.
pub fn winograd_conv2d(
    variant: WinogradVariant,
    input: &Tensor,
    weights: &Tensor,
    pad: (usize, usize),
    pool: Option<&ThreadPool>,
) -> Result<Tensor> {
    if input.rank() == 4 && weights.rank() == 4 {
        // Winograd is a stride-1 algorithm; reject anything else upstream.
    } else {
        bail_unsupported!("winograd_conv2d expects rank-4 input and weights");
    }
    WinogradConvolution::new(variant, weights, pad)?.run(input, pool)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::conv::direct::direct_conv2d;

    fn check_variant(
        v: WinogradVariant,
        n: usize,
        h: usize,
        w: usize,
        c: usize,
        m: usize,
        pad: (usize, usize),
    ) {
        let (kh, kw) = v.kernel();
        let input = Tensor::randn(&[n, h, w, c], 42 + h as u64);
        let weights = Tensor::randn(&[m, kh, kw, c], 7 + c as u64);
        let got = winograd_conv2d(v, &input, &weights, pad, None).unwrap();
        let want = direct_conv2d(&input, &weights, (1, 1), pad).unwrap();
        assert_eq!(got.shape(), want.shape(), "{v}");
        assert!(
            got.allclose(&want, 5e-4),
            "{v} mismatch: rel err {}",
            crate::util::rel_error(got.data(), want.data())
        );
    }

    #[test]
    fn f2x2_3x3_matches_direct() {
        check_variant(WinogradVariant::F2x2_3x3, 1, 8, 8, 4, 8, (0, 0));
        check_variant(WinogradVariant::F2x2_3x3, 2, 9, 11, 3, 5, (1, 1));
    }

    #[test]
    fn f4x4_3x3_matches_direct() {
        check_variant(WinogradVariant::F4x4_3x3, 1, 12, 12, 8, 16, (1, 1));
        check_variant(WinogradVariant::F4x4_3x3, 1, 7, 13, 5, 3, (0, 0));
    }

    #[test]
    fn f6x6_3x3_matches_direct() {
        check_variant(WinogradVariant::F6x6_3x3, 1, 14, 14, 4, 4, (1, 1));
    }

    /// The batched contract: one `[nb, H, W, C]` gathered walk through
    /// `run_fused_batched_into` is **bit-identical** to `nb` sequential
    /// batch-1 `run_fused_into` walks over the same frames — per-region
    /// input/output transforms and per-tile-row GEMM accumulation are
    /// independent of how the region list is partitioned into L2 blocks,
    /// and more frames only lengthen that list — across tile variants ×
    /// ragged shapes × {none, bias, bias+ReLU} epilogues, written into
    /// NaN-poisoned buffers, and to its allocating twin.
    #[test]
    fn property_batched_matches_sequential_bitwise() {
        use crate::testkit::{check, Gen};
        check("winograd batched == nb × batch-1", 24, |g: &mut Gen| {
            let v = *g.choose(&[
                WinogradVariant::F2x2_3x3,
                WinogradVariant::F4x4_3x3,
                WinogradVariant::F6x6_3x3,
            ]);
            let nb = g.usize_in(2, 4);
            let c = g.usize_in(1, 8);
            let m = g.usize_in(1, 10);
            let h = g.usize_in(4, 12);
            let w = g.usize_in(4, 12);
            let input =
                Tensor::from_vec(&[nb, h, w, c], g.normal_vec(nb * h * w * c)).unwrap();
            let weights = Tensor::from_vec(&[m, 3, 3, c], g.normal_vec(m * 9 * c)).unwrap();
            let bias: Vec<f32> = g.normal_vec(m);
            let (bias_opt, act) = match g.usize_in(0, 2) {
                0 => (None, Activation::None),
                1 => (Some(bias.as_slice()), Activation::None),
                _ => (Some(bias.as_slice()), Activation::Relu),
            };
            let conv = WinogradConvolution::new(v, &weights, (1, 1)).unwrap();
            let mut ws = Workspace::new();
            let frame = h * w * c;
            let mut want: Vec<f32> = Vec::new();
            for f in 0..nb {
                let ft = Tensor::from_vec(
                    &[1, h, w, c],
                    input.data()[f * frame..(f + 1) * frame].to_vec(),
                )
                .unwrap();
                want.extend_from_slice(
                    conv.run_fused_with(&ft, None, bias_opt, act, &mut ws).unwrap().data(),
                );
            }
            let mut got = vec![f32::NAN; want.len()];
            conv.run_fused_batched_into(&input.view(), nb, None, bias_opt, act, &mut ws, &mut got)
                .unwrap();
            let twin =
                conv.run_fused_batched_with(&input, nb, None, bias_opt, act, &mut ws).unwrap();
            got.iter().zip(&want).all(|(a, b)| a.to_bits() == b.to_bits())
                && got == *twin.data()
        });
    }

    #[test]
    fn f2x2_5x5_matches_direct() {
        check_variant(WinogradVariant::F2x2_5x5, 1, 12, 12, 4, 6, (2, 2));
        check_variant(WinogradVariant::F2x2_5x5, 1, 9, 9, 3, 4, (0, 0));
    }

    #[test]
    fn f4x4_5x5_matches_direct() {
        check_variant(WinogradVariant::F4x4_5x5, 1, 13, 13, 3, 4, (2, 2));
    }

    #[test]
    fn one_d_variants_match_direct() {
        check_variant(WinogradVariant::F2_1x7, 1, 6, 17, 4, 6, (0, 3));
        check_variant(WinogradVariant::F2_7x1, 1, 17, 6, 4, 6, (3, 0));
        check_variant(WinogradVariant::F4_1x7, 1, 6, 19, 4, 6, (0, 3));
        check_variant(WinogradVariant::F4_7x1, 1, 19, 6, 4, 6, (3, 0));
        check_variant(WinogradVariant::F4_1x3, 1, 5, 15, 3, 4, (0, 1));
        check_variant(WinogradVariant::F4_3x1, 1, 15, 5, 3, 4, (1, 0));
    }

    #[test]
    fn ragged_output_tiles() {
        // Output sizes that don't divide the tile: exercises gather clipping.
        check_variant(WinogradVariant::F4x4_3x3, 1, 9, 10, 3, 5, (1, 1));
        check_variant(WinogradVariant::F2x2_3x3, 1, 6, 5, 2, 3, (0, 0));
    }

    #[test]
    fn parallel_matches_serial() {
        let pool = ThreadPool::new(4);
        let v = WinogradVariant::F4x4_3x3;
        let input = Tensor::randn(&[1, 20, 20, 16], 1);
        let weights = Tensor::randn(&[32, 3, 3, 16], 2);
        let serial = winograd_conv2d(v, &input, &weights, (1, 1), None).unwrap();
        let parallel = winograd_conv2d(v, &input, &weights, (1, 1), Some(&pool)).unwrap();
        assert!(parallel.allclose(&serial, 1e-5));
    }

    #[test]
    fn reusable_transformed_weights() {
        let weights = Tensor::randn(&[8, 3, 3, 4], 3);
        let conv = WinogradConvolution::new(WinogradVariant::F2x2_3x3, &weights, (1, 1)).unwrap();
        for seed in [10, 20] {
            let input = Tensor::randn(&[1, 8, 8, 4], seed);
            let got = conv.run(&input, None).unwrap();
            let want = direct_conv2d(&input, &weights, (1, 1), (1, 1)).unwrap();
            assert!(got.allclose(&want, 5e-4));
        }
    }

    /// The tentpole equivalence (satellite property test): for **every**
    /// shipped variant, on ragged shapes where the region count is not a
    /// multiple of `MR` and the channel counts are not multiples of 4, the
    /// fused pipeline (transform-as-pack + gather-as-epilogue) must match
    /// the staged three-pass pipeline for every epilogue mode
    /// {none, bias, bias+ReLU, bias+ReLU6}, serial and pooled — and both
    /// must match direct convolution with the same bias/activation applied
    /// as a post pass.
    #[test]
    fn fused_matches_staged_all_variants_and_epilogues() {
        let pool = ThreadPool::new(3);
        for v in WinogradVariant::ALL {
            let (kh, kw) = v.kernel();
            // Odd extents ⇒ ragged tile grids; C=5, M=7 ⇒ lane remainders
            // on both sides; regions = 2·tiles_h·tiles_w is generically not
            // a multiple of MR = 6.
            let (h, w) = (kh + 9, kw + 11);
            let (c, m) = (5usize, 7usize);
            let input = Tensor::randn(&[2, h, w, c], 31);
            let weights = Tensor::randn(&[m, kh, kw, c], 32);
            let bias: Vec<f32> = (0..m).map(|i| (i as f32) * 0.5 - 1.5).collect();
            let conv = WinogradConvolution::new(v, &weights, (0, 0)).unwrap();
            let direct = direct_conv2d(&input, &weights, (1, 1), (0, 0)).unwrap();
            for (bias_opt, act) in [
                (None, Activation::None),
                (Some(bias.as_slice()), Activation::None),
                (Some(bias.as_slice()), Activation::Relu),
                (Some(bias.as_slice()), Activation::Relu6),
            ] {
                let mut ws_f = Workspace::new();
                let mut ws_s = Workspace::new();
                let fused = conv
                    .run_fused_with(&input, None, bias_opt, act, &mut ws_f)
                    .unwrap();
                let staged = conv
                    .run_staged_with(&input, None, bias_opt, act, &mut ws_s)
                    .unwrap();
                assert_eq!(fused.shape(), staged.shape(), "{v}");
                assert!(
                    fused.allclose(&staged, 1e-5),
                    "{v} bias={} act={act}: fused != staged, rel err {}",
                    bias_opt.is_some(),
                    crate::util::rel_error(fused.data(), staged.data())
                );
                let fused_pool = conv
                    .run_fused_with(&input, Some(&pool), bias_opt, act, &mut ws_f)
                    .unwrap();
                assert!(
                    fused_pool.allclose(&staged, 1e-5),
                    "{v} bias={} act={act}: pooled fused != staged",
                    bias_opt.is_some()
                );
                // Oracle: direct conv + the same epilogue as a post pass.
                let mut want = direct.clone();
                if bias_opt.is_some() || !act.is_none() {
                    let chans = want.shape()[3];
                    for (i, vv) in want.data_mut().iter_mut().enumerate() {
                        *vv = act.apply(*vv + bias_opt.map_or(0.0, |b| b[i % chans]));
                    }
                }
                assert!(
                    fused.allclose(&want, 2e-3),
                    "{v} bias={} act={act}: fused != direct oracle",
                    bias_opt.is_some()
                );
            }
        }
    }

    /// The write-into refactor (satellite property test): for **every**
    /// shipped variant × {none, bias, bias+ReLU, bias+ReLU6} × ragged
    /// shapes,
    /// `run_fused_into` writing into an offset window of a dirty buffer
    /// (NaN-poisoned, so any unwritten element is caught) must be
    /// **bit-identical** to the PR-2-style allocating entry point — the
    /// staging-based padding and slice output change where bytes live, not
    /// what they are.
    #[test]
    fn write_into_matches_allocating_bitwise() {
        for v in WinogradVariant::ALL {
            let (kh, kw) = v.kernel();
            let (h, w) = (kh + 9, kw + 11);
            let (c, m) = (5usize, 7usize);
            let input = Tensor::randn(&[2, h, w, c], 61);
            let weights = Tensor::randn(&[m, kh, kw, c], 62);
            let bias: Vec<f32> = (0..m).map(|i| (i as f32) * 0.25 - 0.5).collect();
            // Pad so staging is exercised even where the grid would align.
            let conv = WinogradConvolution::new(v, &weights, (kh / 2, kw / 2)).unwrap();
            for (bias_opt, act) in [
                (None, Activation::None),
                (Some(bias.as_slice()), Activation::None),
                (Some(bias.as_slice()), Activation::Relu),
                (Some(bias.as_slice()), Activation::Relu6),
            ] {
                let mut ws_a = Workspace::new();
                let mut ws_b = Workspace::new();
                let want = conv
                    .run_fused_with(&input, None, bias_opt, act, &mut ws_a)
                    .unwrap();
                let off = 7usize; // misaligned window into a larger buffer
                let mut backing = vec![f32::NAN; want.len() + 2 * off];
                conv.run_fused_into(
                    &input.view(),
                    None,
                    bias_opt,
                    act,
                    &mut ws_b,
                    &mut backing[off..off + want.len()],
                )
                .unwrap();
                assert_eq!(
                    &backing[off..off + want.len()],
                    want.data(),
                    "{v} bias={} act={act}: write-into differs from allocating path",
                    bias_opt.is_some()
                );
                assert!(backing[..off].iter().all(|x| x.is_nan()));
                assert!(backing[off + want.len()..].iter().all(|x| x.is_nan()));
                // A wrong-size output slice is rejected, not written.
                assert!(conv
                    .run_fused_into(
                        &input.view(),
                        None,
                        bias_opt,
                        act,
                        &mut ws_b,
                        &mut backing[..want.len() - 1],
                    )
                    .is_err());
            }
        }
    }

    /// Forcing many small region blocks (budget 1 byte ⇒ one region per
    /// block) must reproduce the unblocked result (budget `usize::MAX` ⇒
    /// one block) for every shipped variant, on odd shapes with partial
    /// tiles, serial and pooled.
    #[test]
    fn blocked_matches_unblocked_all_variants() {
        let pool = ThreadPool::new(3);
        for v in WinogradVariant::ALL {
            let (kh, kw) = v.kernel();
            // Odd extents ⇒ ragged tile grids on both axes for 2-D variants.
            let (h, w) = (kh + 9, kw + 11);
            let input = Tensor::randn(&[2, h, w, 5], 3);
            let weights = Tensor::randn(&[7, kh, kw, 5], 4);
            let unblocked = WinogradConvolution::new(v, &weights, (0, 0))
                .unwrap()
                .with_block_budget(usize::MAX);
            let blocked = WinogradConvolution::new(v, &weights, (0, 0))
                .unwrap()
                .with_block_budget(1);
            let want = unblocked.run(&input, None).unwrap();
            let got = blocked.run(&input, None).unwrap();
            assert_eq!(got.shape(), want.shape(), "{v}");
            assert!(got.allclose(&want, 1e-5), "{v}: blocked != unblocked (serial)");
            let got_par = blocked.run(&input, Some(&pool)).unwrap();
            assert!(got_par.allclose(&want, 1e-5), "{v}: blocked != unblocked (pool)");
            let direct = direct_conv2d(&input, &weights, (1, 1), (0, 0)).unwrap();
            assert!(got.allclose(&direct, 2e-3), "{v}: blocked != direct");
        }
    }

    /// A mid-sized budget that yields several multi-region blocks (the
    /// realistic configuration, between the two extremes above).
    #[test]
    fn blocked_mid_budget_matches_direct() {
        let weights = Tensor::randn(&[16, 3, 3, 8], 5);
        // Budget for exactly 2 MR-panels of packed A (12 regions) on a
        // 36-tile, C=8 layer, plus the fixed panel + hot-cube terms.
        let budget = packed_b_panel_bytes(8) + 36 * MR * NR * 4 + 36 * 8 * 4 * (2 * MR);
        let conv = WinogradConvolution::new(WinogradVariant::F4x4_3x3, &weights, (1, 1))
            .unwrap()
            .with_block_budget(budget);
        let rb = conv.regions_per_block(1, 18, 18).unwrap();
        assert!(rb >= 2, "budget should allow several regions, got {rb}");
        let regions = 5 * 5; // ceil(18/4)^2
        assert!(rb < regions, "budget should force multiple blocks, got {rb}");
        let input = Tensor::randn(&[1, 18, 18, 8], 6);
        let got = conv.run(&input, None).unwrap();
        let want = direct_conv2d(&input, &weights, (1, 1), (1, 1)).unwrap();
        assert!(got.allclose(&want, 5e-4));
    }

    /// Repeated fused runs over one arena must not re-grow it, and a
    /// pre-sized arena must never grow at all — the fused path allocates
    /// nothing in steady state.
    #[test]
    fn workspace_reused_across_runs() {
        let weights = Tensor::randn(&[16, 3, 3, 8], 7);
        let conv = WinogradConvolution::new(WinogradVariant::F4x4_3x3, &weights, (1, 1)).unwrap();
        let mut ws = Workspace::new();
        for seed in 0..3 {
            let input = Tensor::randn(&[1, 12, 12, 8], seed + 10);
            let _ = conv.run_fused_with(&input, None, None, Activation::None, &mut ws).unwrap();
        }
        assert_eq!(ws.grow_count(), 1, "one growth on first use, then reuse");

        let elems = conv.workspace_elems_for(1, 12, 12).unwrap();
        let mut presized = Workspace::with_capacity(elems);
        let input = Tensor::randn(&[1, 12, 12, 8], 99);
        let _ = conv
            .run_fused_with(&input, None, None, Activation::None, &mut presized)
            .unwrap();
        assert_eq!(presized.grow_count(), 0, "pre-sized arena must not grow");
        assert_eq!(presized.high_water_elems(), elems, "sizing formula is exact");
    }

    /// The staged pipeline's arena accounting stays exact too (it backs the
    /// E6 ablation baseline).
    #[test]
    fn staged_workspace_accounting_is_exact() {
        let weights = Tensor::randn(&[16, 3, 3, 8], 17);
        let conv = WinogradConvolution::new(WinogradVariant::F4x4_3x3, &weights, (1, 1)).unwrap();
        let mut ws = Workspace::new();
        for seed in 0..2 {
            let input = Tensor::randn(&[1, 12, 12, 8], seed + 50);
            let _ = conv.run_staged_with(&input, None, None, Activation::None, &mut ws).unwrap();
        }
        assert_eq!(ws.grow_count(), 1, "staged arena grows once, then reuses");
        assert_eq!(
            ws.high_water_elems(),
            conv.staged_workspace_elems_for(1, 12, 12).unwrap(),
            "staged sizing formula is exact"
        );
    }

    #[test]
    fn block_sizing_respects_budget() {
        let weights = Tensor::randn(&[32, 3, 3, 16], 8);
        let tiles = WinogradVariant::F4x4_3x3.gemm_count();
        let hot = tiles * MR * NR * 4;
        for budget in [64 * 1024, 256 * 1024, DEFAULT_L2_BUDGET] {
            let conv = WinogradConvolution::new(WinogradVariant::F4x4_3x3, &weights, (1, 1))
                .unwrap()
                .with_block_budget(budget);
            let per_block = conv.block_workspace_bytes(1, 56, 56).unwrap();
            let rb = conv.regions_per_block(1, 56, 56).unwrap();
            // Either the block (plus the fixed B-panel and hot-cube terms)
            // fits the budget, or it degenerated to the 1-region minimum
            // (budget below one MR-panel's footprint).
            assert!(
                per_block + packed_b_panel_bytes(16) + hot <= budget || rb == 1,
                "budget {budget}: per-block {per_block} B, rb {rb}"
            );
            assert!(rb >= 1);
        }
    }

    #[test]
    fn rejects_wrong_kernel_shape() {
        let weights = Tensor::randn(&[8, 5, 5, 4], 3);
        assert!(WinogradConvolution::new(WinogradVariant::F2x2_3x3, &weights, (0, 0)).is_err());
    }

    #[test]
    fn rejects_channel_mismatch() {
        let weights = Tensor::randn(&[8, 3, 3, 4], 3);
        let conv = WinogradConvolution::new(WinogradVariant::F2x2_3x3, &weights, (0, 0)).unwrap();
        let input = Tensor::randn(&[1, 8, 8, 5], 1);
        assert!(conv.run(&input, None).is_err());
    }

    #[test]
    fn rejects_bad_bias_length() {
        let weights = Tensor::randn(&[8, 3, 3, 4], 3);
        let conv = WinogradConvolution::new(WinogradVariant::F2x2_3x3, &weights, (1, 1)).unwrap();
        let input = Tensor::randn(&[1, 8, 8, 4], 1);
        let bias = vec![0.0; 7]; // != 8 output channels
        assert!(conv.run_fused(&input, None, Some(&bias), Activation::None).is_err());
        assert!(conv
            .run_staged_with(&input, None, Some(&bias), Activation::None, &mut Workspace::new())
            .is_err());
    }

    #[test]
    fn workspace_accounting() {
        let weights = Tensor::randn(&[16, 3, 3, 8], 3);
        let conv = WinogradConvolution::new(WinogradVariant::F2x2_3x3, &weights, (1, 1)).unwrap();
        // 8×8 input, pad 1 ⇒ 8×8 output ⇒ 4×4 regions = 16; 16 tiles.
        let ws = conv.workspace_bytes(1, 8, 8).unwrap();
        assert_eq!(ws, 16 * 16 * (8 + 16) * 4);
        // The fused blocked working set never exceeds the staged unblocked
        // one (C is gone; A is padded to whole MR panels but Rb ≤ regions).
        assert!(conv.block_workspace_bytes(1, 8, 8).unwrap() <= ws);
    }
}
