//! Cook-Toom / Winograd minimal-filtering matrix construction over exact
//! rationals.
//!
//! For `F(m, r)` (m outputs, r-tap filter, n = m+r-1 multiplications) with
//! distinct finite interpolation points `α_0 … α_{n-2}` plus the implicit
//! point at infinity, the matrices are:
//!
//! * `G  (n×r)` — filter transform. Row `i ≤ n-2`: `α_i^j / N_i` with
//!   `N_i = Π_{k≠i}(α_i − α_k)`; last row `e_{r-1}`.
//! * `Bᵀ (n×n)` — input transform. Row `i ≤ n-2`: coefficients of
//!   `N_i(x) = Π_{k≠i}(x − α_k)`; last row: coefficients of
//!   `M(x) = Π_k (x − α_k)`.
//! * `Aᵀ (m×n)` — output transform. Column `k ≤ n-2`: `α_k^i`; last column
//!   `e_{m-1}`.
//!
//! Correctness is equivalent to the tensor identity
//! `Σ_k Aᵀ[i][k]·G[k][j]·Bᵀ[k][l] = δ_{l,i+j}` which [`verify_identity`]
//! checks **exactly** (no floating point) — the unit tests run it for every
//! variant the engine ships.

use crate::util::Fraction;

/// A dense matrix of exact rationals.
#[derive(Debug, Clone, PartialEq)]
pub struct FracMatrix {
    /// Row count.
    pub rows: usize,
    /// Column count.
    pub cols: usize,
    /// Row-major entries.
    pub data: Vec<Fraction>,
}

impl FracMatrix {
    /// Zero matrix.
    pub fn zeros(rows: usize, cols: usize) -> FracMatrix {
        FracMatrix {
            rows,
            cols,
            data: vec![Fraction::ZERO; rows * cols],
        }
    }

    /// Entry accessor.
    #[inline]
    pub fn at(&self, i: usize, j: usize) -> Fraction {
        self.data[i * self.cols + j]
    }

    /// Mutable entry accessor.
    #[inline]
    pub fn at_mut(&mut self, i: usize, j: usize) -> &mut Fraction {
        &mut self.data[i * self.cols + j]
    }

    /// Convert to a flat row-major `f32` buffer.
    pub fn to_f32(&self) -> Vec<f32> {
        self.data.iter().map(|f| f.to_f32()).collect()
    }
}

/// The three transform matrices of a 1-D `F(m, r)` algorithm.
#[derive(Debug, Clone)]
pub struct CookToom {
    /// Output count `m`.
    pub m: usize,
    /// Filter taps `r`.
    pub r: usize,
    /// Multiplication count `n = m + r - 1`.
    pub n: usize,
    /// Input transform `Bᵀ (n×n)`.
    pub bt: FracMatrix,
    /// Filter transform `G (n×r)`.
    pub g: FracMatrix,
    /// Output transform `Aᵀ (m×n)`.
    pub at: FracMatrix,
}

/// The canonical interpolation-point sequence. Small values and reciprocal
/// pairs keep both the transform-matrix magnitudes and the floating-point
/// error growth low (the same points wincnn and Lavin use).
pub fn default_points(count: usize) -> Vec<Fraction> {
    let seq: Vec<Fraction> = vec![
        Fraction::int(0),
        Fraction::int(1),
        Fraction::int(-1),
        Fraction::int(2),
        Fraction::int(-2),
        Fraction::new(1, 2),
        Fraction::new(-1, 2),
        Fraction::int(3),
        Fraction::int(-3),
        Fraction::new(1, 3),
        Fraction::new(-1, 3),
        Fraction::int(4),
        Fraction::int(-4),
        Fraction::new(1, 4),
        Fraction::new(-1, 4),
    ];
    assert!(count <= seq.len(), "point sequence exhausted: need {count}");
    seq[..count].to_vec()
}

/// Construct `F(m, r)` with the default point sequence.
pub fn cook_toom(m: usize, r: usize) -> CookToom {
    let n = m + r - 1;
    cook_toom_with_points(m, r, &default_points(n - 1))
}

/// Construct `F(m, r)` from explicit finite points (∞ is implicit).
pub fn cook_toom_with_points(m: usize, r: usize, points: &[Fraction]) -> CookToom {
    assert!(m >= 1 && r >= 1, "F(m,r) needs m,r >= 1");
    let n = m + r - 1;
    assert_eq!(points.len(), n - 1, "need n-1 = {} finite points", n - 1);
    // Points must be distinct.
    for i in 0..points.len() {
        for j in 0..i {
            assert!(points[i] != points[j], "duplicate interpolation point {}", points[i]);
        }
    }

    // Aᵀ (m×n): Vandermonde columns plus the ∞ column e_{m-1}.
    let mut at = FracMatrix::zeros(m, n);
    for (k, &alpha) in points.iter().enumerate() {
        let mut p = Fraction::ONE;
        for i in 0..m {
            *at.at_mut(i, k) = p;
            p = p * alpha;
        }
    }
    *at.at_mut(m - 1, n - 1) = Fraction::ONE;

    // G (n×r): scaled Vandermonde rows plus the ∞ row e_{r-1}.
    let mut g = FracMatrix::zeros(n, r);
    for (i, &alpha) in points.iter().enumerate() {
        let mut norm = Fraction::ONE; // N_i = Π_{k≠i}(α_i - α_k)
        for (k, &beta) in points.iter().enumerate() {
            if k != i {
                norm = norm * (alpha - beta);
            }
        }
        let inv = norm.recip();
        let mut p = Fraction::ONE;
        for j in 0..r {
            *g.at_mut(i, j) = p * inv;
            p = p * alpha;
        }
    }
    *g.at_mut(n - 1, r - 1) = Fraction::ONE;

    // Bᵀ (n×n): rows are the coefficient vectors of N_i(x), last row M(x).
    let mut bt = FracMatrix::zeros(n, n);
    for i in 0..n - 1 {
        let omit: Vec<Fraction> = points
            .iter()
            .enumerate()
            .filter(|(k, _)| *k != i)
            .map(|(_, &a)| a)
            .collect();
        let coeffs = poly_from_roots(&omit); // degree n-2 ⇒ n-1 coefficients
        for (l, &c) in coeffs.iter().enumerate() {
            *bt.at_mut(i, l) = c;
        }
    }
    let m_coeffs = poly_from_roots(points); // degree n-1 ⇒ n coefficients
    for (l, &c) in m_coeffs.iter().enumerate() {
        *bt.at_mut(n - 1, l) = c;
    }

    CookToom { m, r, n, bt, g, at }
}

/// Coefficients (ascending powers) of `Π (x - root_i)`.
fn poly_from_roots(roots: &[Fraction]) -> Vec<Fraction> {
    let mut coeffs = vec![Fraction::ONE]; // the constant polynomial 1
    for &root in roots {
        // multiply by (x - root)
        let mut next = vec![Fraction::ZERO; coeffs.len() + 1];
        for (p, &c) in coeffs.iter().enumerate() {
            next[p + 1] = next[p + 1] + c; // c·x^{p+1}
            next[p] = next[p] - c * root; // -root·c·x^p
        }
        coeffs = next;
    }
    coeffs
}

/// Exactly verify the minimal-filtering identity
/// `Σ_k Aᵀ[i][k] · G[k][j] · Bᵀ[k][l] = δ_{l, i+j}` for all `i<m, j<r, l<n`.
pub fn verify_identity(ct: &CookToom) -> bool {
    for i in 0..ct.m {
        for j in 0..ct.r {
            for l in 0..ct.n {
                let mut s = Fraction::ZERO;
                for k in 0..ct.n {
                    s = s + ct.at.at(i, k) * ct.g.at(k, j) * ct.bt.at(k, l);
                }
                let expect = if l == i + j { Fraction::ONE } else { Fraction::ZERO };
                if s != expect {
                    return false;
                }
            }
        }
    }
    true
}

impl CookToom {
    /// Multiplication saving of the algorithm vs direct: `m·r / n`.
    pub fn theoretical_speedup(&self) -> f64 {
        (self.m * self.r) as f64 / self.n as f64
    }

    /// Apply the algorithm to concrete `f32` data (reference path, used by
    /// tests and the generic pipeline): `y = Aᵀ[(G·g) ⊙ (Bᵀ·d)]`.
    pub fn apply_1d(&self, g_taps: &[f32], d: &[f32]) -> Vec<f32> {
        assert_eq!(g_taps.len(), self.r);
        assert_eq!(d.len(), self.n);
        let gm = self.g.to_f32();
        let btm = self.bt.to_f32();
        let atm = self.at.to_f32();
        // U = G·g  (n)
        let u: Vec<f32> = (0..self.n)
            .map(|i| (0..self.r).map(|j| gm[i * self.r + j] * g_taps[j]).sum())
            .collect();
        // V = Bᵀ·d (n)
        let v: Vec<f32> = (0..self.n)
            .map(|i| (0..self.n).map(|j| btm[i * self.n + j] * d[j]).sum())
            .collect();
        // y = Aᵀ·(U ⊙ V) (m)
        (0..self.m)
            .map(|i| {
                (0..self.n)
                    .map(|k| atm[i * self.n + k] * u[k] * v[k])
                    .sum()
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Direct valid correlation: y[i] = Σ_j g[j]·d[i+j].
    fn direct_correlation(g: &[f32], d: &[f32]) -> Vec<f32> {
        let m = d.len() - g.len() + 1;
        (0..m)
            .map(|i| g.iter().enumerate().map(|(j, &gj)| gj * d[i + j]).sum())
            .collect()
    }

    #[test]
    fn identity_holds_for_all_shipped_variants() {
        for (m, r) in [(2, 3), (4, 3), (2, 5), (2, 7), (6, 3), (4, 5)] {
            let ct = cook_toom(m, r);
            assert!(verify_identity(&ct), "identity failed for F({m},{r})");
        }
    }

    #[test]
    fn f2_3_matches_direct() {
        let ct = cook_toom(2, 3);
        assert_eq!(ct.n, 4);
        let g = [1.0, -2.0, 3.0];
        let d = [4.0, -1.0, 0.5, 2.0];
        let y = ct.apply_1d(&g, &d);
        let want = direct_correlation(&g, &d);
        for (a, b) in y.iter().zip(&want) {
            assert!((a - b).abs() < 1e-5, "{y:?} vs {want:?}");
        }
    }

    #[test]
    fn larger_variants_match_direct() {
        for (m, r) in [(4, 3), (2, 5), (2, 7), (6, 3)] {
            let ct = cook_toom(m, r);
            let mut rng = crate::util::XorShiftRng::new((m * 100 + r) as u64);
            let mut g = vec![0.0; r];
            let mut d = vec![0.0; ct.n];
            rng.fill_normal(&mut g);
            rng.fill_normal(&mut d);
            let y = ct.apply_1d(&g, &d);
            let want = direct_correlation(&g, &d);
            for (a, b) in y.iter().zip(&want) {
                assert!((a - b).abs() < 1e-3, "F({m},{r}): {y:?} vs {want:?}");
            }
        }
    }

    #[test]
    fn theoretical_speedups_match_paper_claims() {
        // F(2,3): 6/4 = 1.5 per dim ⇒ 2.25× in 2D; F(4,3): 12/6 = 2 ⇒ 4×.
        assert!((cook_toom(2, 3).theoretical_speedup() - 1.5).abs() < 1e-9);
        assert!((cook_toom(4, 3).theoretical_speedup() - 2.0).abs() < 1e-9);
        // F(2,5): 10/6 ≈ 1.67 per dim ⇒ 2.78× in 2D.
        assert!((cook_toom(2, 5).theoretical_speedup() - 10.0 / 6.0).abs() < 1e-9);
        // F(2,7): 14/8 = 1.75 (1-D layers: paper measures ~2.0 incl. GEMM reuse).
        assert!((cook_toom(2, 7).theoretical_speedup() - 1.75).abs() < 1e-9);
    }

    #[test]
    fn poly_from_roots_expands() {
        // (x-1)(x+1) = x² - 1
        let c = poly_from_roots(&[Fraction::int(1), Fraction::int(-1)]);
        assert_eq!(c, vec![Fraction::int(-1), Fraction::ZERO, Fraction::ONE]);
        // empty product = 1
        assert_eq!(poly_from_roots(&[]), vec![Fraction::ONE]);
    }

    #[test]
    fn rejects_duplicate_points() {
        let pts = [Fraction::int(0), Fraction::int(1), Fraction::int(1)];
        let r = std::panic::catch_unwind(|| cook_toom_with_points(2, 3, &pts));
        assert!(r.is_err());
    }

    #[test]
    fn identity_fails_for_corrupted_matrix() {
        let mut ct = cook_toom(2, 3);
        *ct.bt.at_mut(0, 0) = Fraction::int(7);
        assert!(!verify_identity(&ct));
    }
}
