//! Hand-unrolled transform kernels for the hottest variants — the analog of
//! the paper's hand-written NEON sequences (Listing 2), operating on four
//! channels per vector under NHWC.
//!
//! These implement **exactly** the matrices produced by
//! [`super::cook_toom`] for the default point set (the unit tests pin them
//! against the generic path), so fast and generic paths are interchangeable
//! inside one convolution.
//!
//! `F(2×2, 3×3)` 1-D building blocks (points 0, 1, −1):
//! ```text
//! Bᵀd: v0 = d2−d0   v1 = d1+d2   v2 = d2−d1   v3 = d3−d1
//! Aᵀm: y0 = m0+m1+m2             y1 = m1−m2+m3
//! ```
//! `F(4×4, 3×3)` (points 0, 1, −1, 2, −2) matches Lavin's published
//! matrices exactly.

use crate::simd::F32x4;

// ---------------------------------------------------------------- F(2x2,3x3)

/// 1-D input transform of `F(2,3)`: 4 values → 4 values.
#[inline(always)]
fn bt4(d: [F32x4; 4]) -> [F32x4; 4] {
    [
        d[2] - d[0], // v0 = d2 − d0
        d[1] + d[2], // v1 = d1 + d2
        d[2] - d[1], // v2 = d2 − d1
        d[3] - d[1], // v3 = d3 − d1
    ]
}

/// 1-D output transform of `F(2,3)`: 4 products → 2 outputs.
#[inline(always)]
fn at4(m: [F32x4; 4]) -> [F32x4; 2] {
    [
        m[0] + m[1] + m[2], // y0
        m[1] - m[2] + m[3], // y1
    ]
}

/// 2-D input transform for `F(2×2, 3×3)`: `V = Bᵀ d B` over a 4×4 tile of
/// channel vectors (row-major `d[i*4+j]`).
pub fn input_transform_4x4(d: &[F32x4], out: &mut [F32x4]) {
    debug_assert!(d.len() >= 16 && out.len() >= 16);
    // Rows: tmp[i][j] = Σ_a Bᵀ[i][a] d[a][j]  — column-wise over j.
    let mut tmp = [F32x4::zero(); 16];
    for j in 0..4 {
        let col = bt4([d[j], d[4 + j], d[8 + j], d[12 + j]]);
        tmp[j] = col[0];
        tmp[4 + j] = col[1];
        tmp[8 + j] = col[2];
        tmp[12 + j] = col[3];
    }
    // Columns: out[i][j] = Σ_b tmp[i][b] Bᵀ[j][b] — row-wise over i.
    for i in 0..4 {
        let row = bt4([tmp[i * 4], tmp[i * 4 + 1], tmp[i * 4 + 2], tmp[i * 4 + 3]]);
        out[i * 4] = row[0];
        out[i * 4 + 1] = row[1];
        out[i * 4 + 2] = row[2];
        out[i * 4 + 3] = row[3];
    }
}

/// 2-D output transform for `F(2×2, 3×3)`: `Y = Aᵀ t A` over a 4×4 tile.
pub fn output_transform_4x4(t: &[F32x4], out: &mut [F32x4]) {
    debug_assert!(t.len() >= 16 && out.len() >= 4);
    let mut tmp = [F32x4::zero(); 8]; // 2×4
    for j in 0..4 {
        let col = at4([t[j], t[4 + j], t[8 + j], t[12 + j]]);
        tmp[j] = col[0];
        tmp[4 + j] = col[1];
    }
    for i in 0..2 {
        let row = at4([tmp[i * 4], tmp[i * 4 + 1], tmp[i * 4 + 2], tmp[i * 4 + 3]]);
        out[i * 2] = row[0];
        out[i * 2 + 1] = row[1];
    }
}

// ---------------------------------------------------------------- F(4x4,3x3)

/// 1-D input transform of `F(4,3)`: 6 values → 6 values (Lavin Bᵀ).
#[inline(always)]
fn bt6(d: [F32x4; 6]) -> [F32x4; 6] {
    let d4_sub_d2 = d[4] - d[2];
    let d3_sub_d1 = d[3] - d[1];
    [
        // v0 = 4d0 − 5d2 + d4
        d[4].fma_scalar(d[0], 4.0).fma_scalar(d[2], -5.0),
        // v1 = (d3 + d4) − 4(d1 + d2)
        (d[3] + d[4]).fma_scalar(d[1] + d[2], -4.0),
        // v2 = (d4 − d3) + 4(d1 − d2)
        (d[4] - d[3]).fma_scalar(d[1] - d[2], 4.0),
        // v3 = (d4 − d2) + 2(d3 − d1)
        d4_sub_d2.fma_scalar(d3_sub_d1, 2.0),
        // v4 = (d4 − d2) − 2(d3 − d1)
        d4_sub_d2.fma_scalar(d3_sub_d1, -2.0),
        // v5 = 4d1 − 5d3 + d5
        d[5].fma_scalar(d[1], 4.0).fma_scalar(d[3], -5.0),
    ]
}

/// 1-D output transform of `F(4,3)`: 6 products → 4 outputs (Lavin Aᵀ).
#[inline(always)]
fn at6(m: [F32x4; 6]) -> [F32x4; 4] {
    let s12 = m[1] + m[2]; // m1 + m2
    let d12 = m[1] - m[2]; // m1 − m2
    let s34 = m[3] + m[4]; // m3 + m4
    let d34 = m[3] - m[4]; // m3 − m4
    [
        m[0] + s12 + s34,                  // y0 = m0 + Σ
        d12.fma_scalar(d34, 2.0),          // y1 = d12 + 2·d34
        s12.fma_scalar(s34, 4.0),          // y2 = s12 + 4·s34
        (d12 + m[5]).fma_scalar(d34, 8.0), // y3 = d12 + 8·d34 + m5
    ]
}

/// 2-D input transform for `F(4×4, 3×3)`: 6×6 tile → 6×6.
pub fn input_transform_6x6(d: &[F32x4], out: &mut [F32x4]) {
    debug_assert!(d.len() >= 36 && out.len() >= 36);
    let mut tmp = [F32x4::zero(); 36];
    for j in 0..6 {
        let col = bt6([d[j], d[6 + j], d[12 + j], d[18 + j], d[24 + j], d[30 + j]]);
        for (i, v) in col.into_iter().enumerate() {
            tmp[i * 6 + j] = v;
        }
    }
    for i in 0..6 {
        let row = bt6([
            tmp[i * 6],
            tmp[i * 6 + 1],
            tmp[i * 6 + 2],
            tmp[i * 6 + 3],
            tmp[i * 6 + 4],
            tmp[i * 6 + 5],
        ]);
        for (j, v) in row.into_iter().enumerate() {
            out[i * 6 + j] = v;
        }
    }
}

/// 2-D output transform for `F(4×4, 3×3)`: 6×6 products → 4×4 outputs.
pub fn output_transform_6x6(t: &[F32x4], out: &mut [F32x4]) {
    debug_assert!(t.len() >= 36 && out.len() >= 16);
    let mut tmp = [F32x4::zero(); 24]; // 4×6
    for j in 0..6 {
        let col = at6([t[j], t[6 + j], t[12 + j], t[18 + j], t[24 + j], t[30 + j]]);
        for (i, v) in col.into_iter().enumerate() {
            tmp[i * 6 + j] = v;
        }
    }
    for i in 0..4 {
        let row = at6([
            tmp[i * 6],
            tmp[i * 6 + 1],
            tmp[i * 6 + 2],
            tmp[i * 6 + 3],
            tmp[i * 6 + 4],
            tmp[i * 6 + 5],
        ]);
        for (j, v) in row.into_iter().enumerate() {
            out[i * 4 + j] = v;
        }
    }
}

// ---------------------------------------------------------------- F(2x2,5x5)
//
// F(2,5) uses the same six interpolation points as F(4,3), so its Bᵀ — and
// therefore the 6×6 input transform — is *identical* to [`bt6`]; only the
// output transform differs (Aᵀ is 2×6).

/// 1-D output transform of `F(2,5)`: 6 products → 2 outputs.
/// Aᵀ rows: `[1,1,1,1,1,0]`, `[0,1,−1,2,−2,1]`.
#[inline(always)]
fn at2_6(m: [F32x4; 6]) -> [F32x4; 2] {
    [
        m[0] + m[1] + m[2] + m[3] + m[4],
        (m[1] - m[2] + m[5]).fma_scalar(m[3] - m[4], 2.0),
    ]
}

/// 2-D output transform for `F(2×2, 5×5)`: 6×6 products → 2×2 outputs.
pub fn output_transform_6x6_to_2x2(t: &[F32x4], out: &mut [F32x4]) {
    debug_assert!(t.len() >= 36 && out.len() >= 4);
    let mut tmp = [F32x4::zero(); 12]; // 2×6
    for j in 0..6 {
        let col = at2_6([t[j], t[6 + j], t[12 + j], t[18 + j], t[24 + j], t[30 + j]]);
        tmp[j] = col[0];
        tmp[6 + j] = col[1];
    }
    for i in 0..2 {
        let row = at2_6([
            tmp[i * 6],
            tmp[i * 6 + 1],
            tmp[i * 6 + 2],
            tmp[i * 6 + 3],
            tmp[i * 6 + 4],
            tmp[i * 6 + 5],
        ]);
        out[i * 2] = row[0];
        out[i * 2 + 1] = row[1];
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::winograd::transform::transform_tile_lanes;
    use crate::winograd::{WinogradPlan, WinogradVariant};

    fn random_lanes(n: usize, seed: u64) -> Vec<F32x4> {
        let mut rng = crate::util::XorShiftRng::new(seed);
        (0..n)
            .map(|_| F32x4::from_array([rng.normal(), rng.normal(), rng.normal(), rng.normal()]))
            .collect()
    }

    fn assert_lanes_close(a: &[F32x4], b: &[F32x4], tol: f32) {
        assert_eq!(a.len(), b.len());
        for (i, (x, y)) in a.iter().zip(b).enumerate() {
            for l in 0..4 {
                assert!(
                    (x.lane(l) - y.lane(l)).abs() < tol,
                    "elem {i} lane {l}: {} vs {}",
                    x.lane(l),
                    y.lane(l)
                );
            }
        }
    }

    #[test]
    fn input_4x4_matches_generic() {
        let plan = WinogradPlan::new(WinogradVariant::F2x2_3x3);
        let d = random_lanes(16, 1);
        let mut fast = vec![F32x4::zero(); 16];
        input_transform_4x4(&d, &mut fast);
        let mut generic = vec![F32x4::zero(); 16];
        let mut tmp = vec![F32x4::zero(); 16];
        transform_tile_lanes(&plan.h.bt, &plan.w.bt, &d, &mut generic, &mut tmp);
        assert_lanes_close(&fast, &generic, 1e-4);
    }

    #[test]
    fn output_4x4_matches_generic() {
        let plan = WinogradPlan::new(WinogradVariant::F2x2_3x3);
        let t = random_lanes(16, 2);
        let mut fast = vec![F32x4::zero(); 4];
        output_transform_4x4(&t, &mut fast);
        let mut generic = vec![F32x4::zero(); 4];
        let mut tmp = vec![F32x4::zero(); 8];
        transform_tile_lanes(&plan.h.at, &plan.w.at, &t, &mut generic, &mut tmp);
        assert_lanes_close(&fast, &generic, 1e-4);
    }

    #[test]
    fn input_6x6_matches_generic() {
        let plan = WinogradPlan::new(WinogradVariant::F4x4_3x3);
        let d = random_lanes(36, 3);
        let mut fast = vec![F32x4::zero(); 36];
        input_transform_6x6(&d, &mut fast);
        let mut generic = vec![F32x4::zero(); 36];
        let mut tmp = vec![F32x4::zero(); 36];
        transform_tile_lanes(&plan.h.bt, &plan.w.bt, &d, &mut generic, &mut tmp);
        assert_lanes_close(&fast, &generic, 1e-3);
    }

    #[test]
    fn f2x2_5x5_shares_bt6_and_output_matches_generic() {
        // Input transform: the F(2×2,5×5) plan's Bᵀ must equal F(4×4,3×3)'s.
        let p33 = WinogradPlan::new(WinogradVariant::F4x4_3x3);
        let p55 = WinogradPlan::new(WinogradVariant::F2x2_5x5);
        assert_eq!(p33.h.bt, p55.h.bt, "same points ⇒ same Bᵀ");
        // Output transform: fast path vs generic.
        let t = random_lanes(36, 9);
        let mut fast = vec![F32x4::zero(); 4];
        output_transform_6x6_to_2x2(&t, &mut fast);
        let mut generic = vec![F32x4::zero(); 4];
        let mut tmp = vec![F32x4::zero(); 12];
        transform_tile_lanes(&p55.h.at, &p55.w.at, &t, &mut generic, &mut tmp);
        assert_lanes_close(&fast, &generic, 1e-3);
    }

    #[test]
    fn output_6x6_matches_generic() {
        let plan = WinogradPlan::new(WinogradVariant::F4x4_3x3);
        let t = random_lanes(36, 4);
        let mut fast = vec![F32x4::zero(); 16];
        output_transform_6x6(&t, &mut fast);
        let mut generic = vec![F32x4::zero(); 16];
        let mut tmp = vec![F32x4::zero(); 24];
        transform_tile_lanes(&plan.h.at, &plan.w.at, &t, &mut generic, &mut tmp);
        assert_lanes_close(&fast, &generic, 1e-3);
    }
}
