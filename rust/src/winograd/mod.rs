//! The paper's contribution: region-wise multi-channel Winograd / Cook-Toom
//! convolution.
//!
//! * [`cook_toom`] — exact construction of the `Bᵀ/G/Aᵀ` transform matrices
//!   for any `F(m, r)`, verified against the minimal-filtering identity.
//! * [`transform`] — channel-lane (SIMD) tile transforms: the NHWC
//!   formulation of the paper's Listing 2, generic over the variant.
//! * [`fast`] — hard-coded add/sub transform kernels for the hottest
//!   variants, exactly like the paper's hand-written NEON sequences.
//! * [`convolve`] — the fused two-stage pipeline: input transform written
//!   straight into packed GEMM panels (*transform-as-pack*) → `x²` batched
//!   GEMMs whose epilogue is the output transform (*gather-as-epilogue*);
//!   the staged three-pass flow is kept as the ablation baseline.
//!
//! Variant naming follows the paper's `F(z×z, w×w, x×x)`: output tile,
//! filter, input tile.

pub mod cook_toom;
pub mod transform;
pub mod fast;
pub mod convolve;

pub use convolve::{winograd_conv2d, WinogradConvolution};
pub use cook_toom::{cook_toom, CookToom};

use crate::{bail_unsupported, Result};

/// A dense row-major `f32` matrix (transform matrices are tiny: ≤ 8×8).
#[derive(Debug, Clone, PartialEq)]
pub struct MatF {
    /// Row count.
    pub rows: usize,
    /// Column count.
    pub cols: usize,
    /// Row-major entries.
    pub data: Vec<f32>,
}

impl MatF {
    /// Build from rows×cols and flat data.
    pub fn new(rows: usize, cols: usize, data: Vec<f32>) -> MatF {
        assert_eq!(data.len(), rows * cols);
        MatF { rows, cols, data }
    }

    /// The 1×1 identity (used for the passive axis of 1-D variants).
    pub fn identity1() -> MatF {
        MatF::new(1, 1, vec![1.0])
    }

    /// Entry accessor.
    #[inline(always)]
    pub fn at(&self, i: usize, j: usize) -> f32 {
        self.data[i * self.cols + j]
    }
}

/// The shipped algorithm variants (the paper implements five; the `F6x6_3x3`
/// and 1-D 3-tap variants are the paper's natural extensions and feed the
/// ablation benches).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum WinogradVariant {
    /// `F(2×2, 3×3, 4×4)` — 16 GEMMs, 2.25× theoretical multiply saving.
    F2x2_3x3,
    /// `F(4×4, 3×3, 6×6)` — 36 GEMMs, 4× theoretical.
    F4x4_3x3,
    /// `F(6×6, 3×3, 8×8)` — 64 GEMMs, 5.06× theoretical (extension).
    F6x6_3x3,
    /// `F(2×2, 5×5, 6×6)` — 36 GEMMs, 2.78× theoretical.
    F2x2_5x5,
    /// `F(4×4, 5×5, 8×8)` — 64 GEMMs, 6.25× theoretical (extension).
    F4x4_5x5,
    /// 1-D Cook-Toom `F(2, 7)` on a `1×7` filter (Inception-v3 rows).
    F2_1x7,
    /// 1-D Cook-Toom `F(4, 7)` on a `1×7` filter — 10 points, 2.8×
    /// theoretical; the default for 1×7 since EXPERIMENTS.md §Perf step 5.
    F4_1x7,
    /// 1-D Cook-Toom `F(4, 7)` on a `7×1` filter.
    F4_7x1,
    /// 1-D Cook-Toom `F(2, 7)` on a `7×1` filter (Inception-v3 columns).
    F2_7x1,
    /// 1-D Cook-Toom `F(4, 3)` on a `1×3` filter (extension).
    F4_1x3,
    /// 1-D Cook-Toom `F(4, 3)` on a `3×1` filter (extension).
    F4_3x1,
}

impl WinogradVariant {
    /// Every shipped variant (ablation sweeps iterate this).
    pub const ALL: [WinogradVariant; 11] = [
        WinogradVariant::F2x2_3x3,
        WinogradVariant::F4x4_3x3,
        WinogradVariant::F6x6_3x3,
        WinogradVariant::F2x2_5x5,
        WinogradVariant::F4x4_5x5,
        WinogradVariant::F2_1x7,
        WinogradVariant::F4_1x7,
        WinogradVariant::F4_7x1,
        WinogradVariant::F2_7x1,
        WinogradVariant::F4_1x3,
        WinogradVariant::F4_3x1,
    ];

    /// `(kh, kw)` of the filter this variant accepts.
    pub fn kernel(&self) -> (usize, usize) {
        match self {
            WinogradVariant::F2x2_3x3 | WinogradVariant::F4x4_3x3 | WinogradVariant::F6x6_3x3 => (3, 3),
            WinogradVariant::F2x2_5x5 | WinogradVariant::F4x4_5x5 => (5, 5),
            WinogradVariant::F2_1x7 | WinogradVariant::F4_1x7 => (1, 7),
            WinogradVariant::F2_7x1 | WinogradVariant::F4_7x1 => (7, 1),
            WinogradVariant::F4_1x3 => (1, 3),
            WinogradVariant::F4_3x1 => (3, 1),
        }
    }

    /// `(mh, mw)` output-tile shape.
    pub fn out_tile(&self) -> (usize, usize) {
        match self {
            WinogradVariant::F2x2_3x3 | WinogradVariant::F2x2_5x5 => (2, 2),
            WinogradVariant::F4x4_3x3 | WinogradVariant::F4x4_5x5 => (4, 4),
            WinogradVariant::F6x6_3x3 => (6, 6),
            WinogradVariant::F2_1x7 => (1, 2),
            WinogradVariant::F4_1x7 => (1, 4),
            WinogradVariant::F2_7x1 => (2, 1),
            WinogradVariant::F4_7x1 => (4, 1),
            WinogradVariant::F4_1x3 => (1, 4),
            WinogradVariant::F4_3x1 => (4, 1),
        }
    }

    /// `(th, tw)` input-tile shape (`t = m + r - 1` per active axis).
    pub fn in_tile(&self) -> (usize, usize) {
        let (kh, kw) = self.kernel();
        let (mh, mw) = self.out_tile();
        (mh + kh - 1, mw + kw - 1)
    }

    /// Number of GEMMs (`th·tw`) in the batched stage.
    pub fn gemm_count(&self) -> usize {
        let (th, tw) = self.in_tile();
        th * tw
    }

    /// Theoretical multiply-reduction vs direct convolution.
    pub fn theoretical_speedup(&self) -> f64 {
        let (kh, kw) = self.kernel();
        let (mh, mw) = self.out_tile();
        let (th, tw) = self.in_tile();
        (kh * kw * mh * mw) as f64 / (th * tw) as f64
    }

    /// Short display name matching the paper's notation.
    pub fn name(&self) -> &'static str {
        match self {
            WinogradVariant::F2x2_3x3 => "F(2x2,3x3)",
            WinogradVariant::F4x4_3x3 => "F(4x4,3x3)",
            WinogradVariant::F6x6_3x3 => "F(6x6,3x3)",
            WinogradVariant::F2x2_5x5 => "F(2x2,5x5)",
            WinogradVariant::F4x4_5x5 => "F(4x4,5x5)",
            WinogradVariant::F2_1x7 => "F(2,1x7)",
            WinogradVariant::F4_1x7 => "F(4,1x7)",
            WinogradVariant::F2_7x1 => "F(2,7x1)",
            WinogradVariant::F4_7x1 => "F(4,7x1)",
            WinogradVariant::F4_1x3 => "F(4,1x3)",
            WinogradVariant::F4_3x1 => "F(4,3x1)",
        }
    }

    /// The variant that handles a `(kh, kw)` stride-1 filter, if any —
    /// the default selection policy (see `conv::select` for the full
    /// heuristic).
    pub fn for_kernel(kh: usize, kw: usize) -> Option<WinogradVariant> {
        match (kh, kw) {
            (3, 3) => Some(WinogradVariant::F4x4_3x3),
            (5, 5) => Some(WinogradVariant::F2x2_5x5),
            (1, 7) => Some(WinogradVariant::F4_1x7),
            (7, 1) => Some(WinogradVariant::F4_7x1),
            (1, 3) => Some(WinogradVariant::F4_1x3),
            (3, 1) => Some(WinogradVariant::F4_3x1),
            _ => None,
        }
    }
}

impl std::fmt::Display for WinogradVariant {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}", self.name())
    }
}

/// Per-axis transform matrices in `f32` form.
#[derive(Debug, Clone)]
pub struct AxisTransforms {
    /// Input-tile extent on this axis.
    pub t: usize,
    /// Output-tile extent on this axis.
    pub m: usize,
    /// Filter extent on this axis.
    pub r: usize,
    /// Input transform `Bᵀ (t×t)`.
    pub bt: MatF,
    /// Filter transform `G (t×r)`.
    pub g: MatF,
    /// Output transform `Aᵀ (m×t)`.
    pub at: MatF,
}

impl AxisTransforms {
    /// The passive axis of a 1-D variant: everything is 1×1 identity.
    pub fn identity() -> AxisTransforms {
        AxisTransforms {
            t: 1,
            m: 1,
            r: 1,
            bt: MatF::identity1(),
            g: MatF::identity1(),
            at: MatF::identity1(),
        }
    }

    /// Build from an exact Cook-Toom construction.
    pub fn from_cook_toom(ct: &CookToom) -> AxisTransforms {
        AxisTransforms {
            t: ct.n,
            m: ct.m,
            r: ct.r,
            bt: MatF::new(ct.n, ct.n, ct.bt.to_f32()),
            g: MatF::new(ct.n, ct.r, ct.g.to_f32()),
            at: MatF::new(ct.m, ct.n, ct.at.to_f32()),
        }
    }
}

/// A fully-materialised plan: per-axis matrices plus derived extents.
#[derive(Debug, Clone)]
pub struct WinogradPlan {
    /// Which variant this plan implements.
    pub variant: WinogradVariant,
    /// Vertical-axis transforms.
    pub h: AxisTransforms,
    /// Horizontal-axis transforms.
    pub w: AxisTransforms,
}

impl WinogradPlan {
    /// Materialise the plan for a variant (matrices built exactly, then
    /// converted to `f32`).
    pub fn new(variant: WinogradVariant) -> WinogradPlan {
        let (kh, kw) = variant.kernel();
        let (mh, mw) = variant.out_tile();
        let axis = |m: usize, r: usize| -> AxisTransforms {
            if r == 1 {
                AxisTransforms::identity()
            } else {
                AxisTransforms::from_cook_toom(&cook_toom(m, r))
            }
        };
        WinogradPlan {
            variant,
            h: axis(mh, kh),
            w: axis(mw, kw),
        }
    }

    /// Validate that a filter shape matches this plan.
    pub fn check_kernel(&self, kh: usize, kw: usize) -> Result<()> {
        let (ekh, ekw) = self.variant.kernel();
        if (kh, kw) != (ekh, ekw) {
            bail_unsupported!(
                "{} expects a {}x{} filter, got {}x{}",
                self.variant,
                ekh,
                ekw,
                kh,
                kw
            );
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn variant_geometry_is_consistent() {
        for v in WinogradVariant::ALL {
            let (kh, kw) = v.kernel();
            let (mh, mw) = v.out_tile();
            let (th, tw) = v.in_tile();
            assert_eq!(th, mh + kh - 1, "{v}");
            assert_eq!(tw, mw + kw - 1, "{v}");
            assert_eq!(v.gemm_count(), th * tw);
            assert!(v.theoretical_speedup() > 1.0, "{v}");
        }
    }

    #[test]
    fn headline_theoretical_speedups() {
        assert!((WinogradVariant::F2x2_3x3.theoretical_speedup() - 2.25).abs() < 1e-9);
        assert!((WinogradVariant::F4x4_3x3.theoretical_speedup() - 4.0).abs() < 1e-9);
        assert!((WinogradVariant::F2_1x7.theoretical_speedup() - 1.75).abs() < 1e-9);
    }

    #[test]
    fn plans_have_matching_matrix_shapes() {
        for v in WinogradVariant::ALL {
            let p = WinogradPlan::new(v);
            assert_eq!(p.h.bt.rows, p.h.t);
            assert_eq!(p.h.bt.cols, p.h.t);
            assert_eq!(p.h.g.rows, p.h.t);
            assert_eq!(p.h.g.cols, p.h.r);
            assert_eq!(p.h.at.rows, p.h.m);
            assert_eq!(p.h.at.cols, p.h.t);
            assert_eq!(p.w.bt.rows, p.w.t);
        }
    }

    #[test]
    fn one_d_variants_have_identity_axis() {
        let p = WinogradPlan::new(WinogradVariant::F2_1x7);
        assert_eq!(p.h.t, 1);
        assert_eq!(p.w.t, 8);
        let p = WinogradPlan::new(WinogradVariant::F2_7x1);
        assert_eq!(p.h.t, 8);
        assert_eq!(p.w.t, 1);
    }

    #[test]
    fn kernel_check() {
        let p = WinogradPlan::new(WinogradVariant::F4x4_3x3);
        assert!(p.check_kernel(3, 3).is_ok());
        assert!(p.check_kernel(5, 5).is_err());
    }

    #[test]
    fn for_kernel_selects_expected_variants() {
        assert_eq!(WinogradVariant::for_kernel(3, 3), Some(WinogradVariant::F4x4_3x3));
        assert_eq!(WinogradVariant::for_kernel(5, 5), Some(WinogradVariant::F2x2_5x5));
        assert_eq!(WinogradVariant::for_kernel(1, 7), Some(WinogradVariant::F4_1x7));
        assert_eq!(WinogradVariant::for_kernel(7, 1), Some(WinogradVariant::F4_7x1));
        assert_eq!(WinogradVariant::for_kernel(1, 1), None);
        assert_eq!(WinogradVariant::for_kernel(11, 11), None);
    }
}
