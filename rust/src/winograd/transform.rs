//! Generic channel-lane tile transforms.
//!
//! Under NHWC, one [`F32x4`] holds four channels of one pixel, so a tile of
//! `th×tw` pixels (for one 4-channel group) is `th·tw` vectors and the 2-D
//! transform `T_L · tile · T_Rᵀ` is two passes of small row combinations over
//! whole vectors — the NHWC formulation of the paper's Listing 2, generic
//! over the transform matrices so every `F(m, r)` variant shares this code.
//! The hottest variants additionally have hand-unrolled versions in
//! [`super::fast`].

use super::MatF;
use crate::simd::F32x4;

/// `out[p×q] = L (p×a) · tile (a×b) · Rᵀ  — with R given as (q×b)` —
/// over `F32x4` channel lanes.
///
/// `tmp` must hold `p·b` vectors; `out` must hold `p·q`.
#[inline]
pub fn transform_tile_lanes(
    l: &MatF,
    r: &MatF,
    tile: &[F32x4],
    out: &mut [F32x4],
    tmp: &mut [F32x4],
) {
    let (p, a) = (l.rows, l.cols);
    let (q, b) = (r.rows, r.cols);
    debug_assert_eq!(tile.len(), a * b);
    debug_assert!(tmp.len() >= p * b);
    debug_assert!(out.len() >= p * q);

    // Pass 1: tmp[i][j] = Σ_k L[i][k] · tile[k][j]
    for i in 0..p {
        for j in 0..b {
            let mut acc = F32x4::zero();
            for k in 0..a {
                let c = l.at(i, k);
                if c != 0.0 {
                    acc = acc.fma_scalar(tile[k * b + j], c);
                }
            }
            tmp[i * b + j] = acc;
        }
    }
    // Pass 2: out[i][j] = Σ_k tmp[i][k] · R[j][k]
    for i in 0..p {
        for j in 0..q {
            let mut acc = F32x4::zero();
            for k in 0..b {
                let c = r.at(j, k);
                if c != 0.0 {
                    acc = acc.fma_scalar(tmp[i * b + k], c);
                }
            }
            out[i * q + j] = acc;
        }
    }
}

/// Scalar version of [`transform_tile_lanes`] for the (once-per-layer)
/// weight transform: `out[p×q] = L · tile · Rᵀ`.
pub fn transform_tile_scalar(l: &MatF, r: &MatF, tile: &[f32], out: &mut [f32], tmp: &mut [f32]) {
    let (p, a) = (l.rows, l.cols);
    let (q, b) = (r.rows, r.cols);
    debug_assert_eq!(tile.len(), a * b);
    for i in 0..p {
        for j in 0..b {
            let mut acc = 0.0;
            for k in 0..a {
                acc += l.at(i, k) * tile[k * b + j];
            }
            tmp[i * b + j] = acc;
        }
    }
    for i in 0..p {
        for j in 0..q {
            let mut acc = 0.0;
            for k in 0..b {
                acc += tmp[i * b + k] * r.at(j, k);
            }
            out[i * q + j] = acc;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::XorShiftRng;

    /// Naive reference: out = L · tile · Rᵀ with plain nested loops.
    fn reference(l: &MatF, r: &MatF, tile: &[f32]) -> Vec<f32> {
        let (p, a) = (l.rows, l.cols);
        let (q, b) = (r.rows, r.cols);
        let mut out = vec![0.0; p * q];
        for i in 0..p {
            for j in 0..q {
                let mut acc = 0.0;
                for x in 0..a {
                    for y in 0..b {
                        acc += l.at(i, x) * tile[x * b + y] * r.at(j, y);
                    }
                }
                out[i * q + j] = acc;
            }
        }
        out
    }

    fn random_mat(rows: usize, cols: usize, seed: u64) -> MatF {
        let mut rng = XorShiftRng::new(seed);
        let mut data = vec![0.0; rows * cols];
        rng.fill_normal(&mut data);
        MatF::new(rows, cols, data)
    }

    #[test]
    fn scalar_matches_reference() {
        let l = random_mat(4, 6, 1);
        let r = random_mat(3, 5, 2);
        let mut rng = XorShiftRng::new(3);
        let mut tile = vec![0.0; 6 * 5];
        rng.fill_normal(&mut tile);
        let mut out = vec![0.0; 4 * 3];
        let mut tmp = vec![0.0; 4 * 5];
        transform_tile_scalar(&l, &r, &tile, &mut out, &mut tmp);
        let want = reference(&l, &r, &tile);
        for (a, b) in out.iter().zip(&want) {
            assert!((a - b).abs() < 1e-4);
        }
    }

    #[test]
    fn lanes_match_scalar_per_lane() {
        let l = random_mat(6, 6, 4);
        let r = random_mat(6, 6, 5);
        let mut rng = XorShiftRng::new(6);
        // One tile of 6×6 pixels × 4 channels.
        let mut lanes = vec![F32x4::zero(); 36];
        for v in lanes.iter_mut() {
            *v = F32x4([rng.normal(), rng.normal(), rng.normal(), rng.normal()]);
        }
        let mut out = vec![F32x4::zero(); 36];
        let mut tmp = vec![F32x4::zero(); 36];
        transform_tile_lanes(&l, &r, &lanes, &mut out, &mut tmp);

        for lane in 0..4 {
            let tile: Vec<f32> = lanes.iter().map(|v| v.0[lane]).collect();
            let want = reference(&l, &r, &tile);
            for (i, w) in want.iter().enumerate() {
                assert!(
                    (out[i].0[lane] - w).abs() < 1e-3,
                    "lane {lane} elem {i}: {} vs {w}",
                    out[i].0[lane]
                );
            }
        }
    }

    #[test]
    fn identity_axes_passthrough() {
        // L = 1×1 identity, R = 4×4 identity ⇒ out == tile (1×4).
        let l = MatF::identity1();
        let eye = MatF::new(
            4,
            4,
            (0..16).map(|i| if i % 5 == 0 { 1.0 } else { 0.0 }).collect(),
        );
        let tile = [
            F32x4::splat(1.0),
            F32x4::splat(2.0),
            F32x4::splat(3.0),
            F32x4::splat(4.0),
        ];
        let mut out = [F32x4::zero(); 4];
        let mut tmp = [F32x4::zero(); 4];
        transform_tile_lanes(&l, &eye, &tile, &mut out, &mut tmp);
        for (o, t) in out.iter().zip(&tile) {
            assert_eq!(o, t);
        }
    }
}
