//! Generic channel-lane tile transforms.
//!
//! Under NHWC, one [`F32x4`] holds four channels of one pixel, so a tile of
//! `th×tw` pixels (for one 4-channel group) is `th·tw` vectors and the 2-D
//! transform `T_L · tile · T_Rᵀ` is two passes of small row combinations over
//! whole vectors — the NHWC formulation of the paper's Listing 2, generic
//! over the transform matrices so every `F(m, r)` variant shares this code.
//! The hottest variants additionally have hand-unrolled versions in
//! [`super::fast`].
//!
//! [`transform_and_pack`] is the fused pipeline's stage 1
//! (**transform-as-pack**): it dispatches the input transform (fast path
//! where available) and scatters the resulting Winograd-domain values
//! straight into the GEMM's `MR`-strided packed-A panel cells — the
//! values' first and only materialisation, deleting the row-major A
//! staging buffer and the GEMM's `pack_a` copy pass.

use super::{fast, MatF, WinogradPlan, WinogradVariant};
use crate::gemm::pack::packed_a_index;
use crate::gemm::MR;
use crate::simd::F32x4;

/// `out[p×q] = L (p×a) · tile (a×b) · Rᵀ  — with R given as (q×b)` —
/// over `F32x4` channel lanes.
///
/// `tmp` must hold `p·b` vectors; `out` must hold `p·q`.
#[inline]
pub fn transform_tile_lanes(
    l: &MatF,
    r: &MatF,
    tile: &[F32x4],
    out: &mut [F32x4],
    tmp: &mut [F32x4],
) {
    let (p, a) = (l.rows, l.cols);
    let (q, b) = (r.rows, r.cols);
    debug_assert_eq!(tile.len(), a * b);
    debug_assert!(tmp.len() >= p * b);
    debug_assert!(out.len() >= p * q);

    // Pass 1: tmp[i][j] = Σ_k L[i][k] · tile[k][j]
    for i in 0..p {
        for j in 0..b {
            let mut acc = F32x4::zero();
            for k in 0..a {
                let c = l.at(i, k);
                if c != 0.0 {
                    acc = acc.fma_scalar(tile[k * b + j], c);
                }
            }
            tmp[i * b + j] = acc;
        }
    }
    // Pass 2: out[i][j] = Σ_k tmp[i][k] · R[j][k]
    for i in 0..p {
        for j in 0..q {
            let mut acc = F32x4::zero();
            for k in 0..b {
                let c = r.at(j, k);
                if c != 0.0 {
                    acc = acc.fma_scalar(tmp[i * b + k], c);
                }
            }
            out[i * q + j] = acc;
        }
    }
}

/// Input-transform one region's `th×tw` tile of channel lanes (`d`) for
/// `plan`'s variant and scatter the `x²` results directly into per-tile
/// packed-A panels (transform-as-pack).
///
/// * `a_addr`/`a_len` — base address and length (in `f32`s) of the block's
///   whole packed-A buffer: `x²` per-tile images of `a_stride` elements
///   each, laid out by [`packed_a_index`] over `k` logical columns (input
///   channels). The address form (the crate's raw-window idiom) exists
///   because regions packing in parallel write interleaved scalar cells of
///   shared panels — no two regions' cells overlap, but they cannot be
///   expressed as disjoint subslices.
/// * `row` — the region's block-local index (the logical A row). **The
///   caller must guarantee no other thread concurrently writes this row's
///   cells** (parallelising over regions satisfies this).
/// * `col`, `lanes` — this 4-channel group: tile `t`'s value lands in
///   cells `(row, col..col+lanes)` of A_t, which sit `MR` apart in packed
///   layout ([`crate::gemm::pack::PackedAWriter`] is the safe
///   single-threaded face of the same layout).
/// * `out`/`tmp` — caller scratch, ≥ `th·tw` lanes each.
///
/// Fast-path dispatch matches the staged pipeline: `F(2×2,3×3)` and the
/// 6×6 variants use the hand-unrolled kernels (`F(2,5)` shares `F(4,3)`'s
/// interpolation points, hence the identical 6×6 Bᵀ — pinned by a fast.rs
/// test); everything else goes through [`transform_tile_lanes`].
#[allow(clippy::too_many_arguments)]
#[inline]
pub fn transform_and_pack(
    plan: &WinogradPlan,
    d: &[F32x4],
    out: &mut [F32x4],
    tmp: &mut [F32x4],
    a_addr: usize,
    a_len: usize,
    a_stride: usize,
    k: usize,
    row: usize,
    col: usize,
    lanes: usize,
) {
    let tiles = plan.h.t * plan.w.t;
    debug_assert_eq!(d.len(), tiles);
    debug_assert!(a_len >= tiles * a_stride);
    debug_assert!(col + lanes <= k && lanes <= 4);
    match plan.variant {
        WinogradVariant::F2x2_3x3 => fast::input_transform_4x4(d, out),
        WinogradVariant::F4x4_3x3 | WinogradVariant::F2x2_5x5 => fast::input_transform_6x6(d, out),
        _ => transform_tile_lanes(&plan.h.bt, &plan.w.bt, d, out, tmp),
    }
    let base = packed_a_index(k, row, col);
    for (t, v) in out[..tiles].iter().enumerate() {
        let cell = t * a_stride + base;
        let vals = v.to_array();
        for (l, &x) in vals[..lanes].iter().enumerate() {
            let idx = cell + l * MR;
            debug_assert!(idx < a_len);
            // SAFETY: per the contract above, cell (row, col + l) of tile t
            // is written by exactly one caller; cells are disjoint scalars.
            unsafe { *(a_addr as *mut f32).add(idx) = x };
        }
    }
}

/// Scalar version of [`transform_tile_lanes`] for the (once-per-layer)
/// weight transform: `out[p×q] = L · tile · Rᵀ`.
pub fn transform_tile_scalar(l: &MatF, r: &MatF, tile: &[f32], out: &mut [f32], tmp: &mut [f32]) {
    let (p, a) = (l.rows, l.cols);
    let (q, b) = (r.rows, r.cols);
    debug_assert_eq!(tile.len(), a * b);
    for i in 0..p {
        for j in 0..b {
            let mut acc = 0.0;
            for k in 0..a {
                acc += l.at(i, k) * tile[k * b + j];
            }
            tmp[i * b + j] = acc;
        }
    }
    for i in 0..p {
        for j in 0..q {
            let mut acc = 0.0;
            for k in 0..b {
                acc += tmp[i * b + k] * r.at(j, k);
            }
            out[i * q + j] = acc;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::XorShiftRng;

    /// Naive reference: out = L · tile · Rᵀ with plain nested loops.
    fn reference(l: &MatF, r: &MatF, tile: &[f32]) -> Vec<f32> {
        let (p, a) = (l.rows, l.cols);
        let (q, b) = (r.rows, r.cols);
        let mut out = vec![0.0; p * q];
        for i in 0..p {
            for j in 0..q {
                let mut acc = 0.0;
                for x in 0..a {
                    for y in 0..b {
                        acc += l.at(i, x) * tile[x * b + y] * r.at(j, y);
                    }
                }
                out[i * q + j] = acc;
            }
        }
        out
    }

    fn random_mat(rows: usize, cols: usize, seed: u64) -> MatF {
        let mut rng = XorShiftRng::new(seed);
        let mut data = vec![0.0; rows * cols];
        rng.fill_normal(&mut data);
        MatF::new(rows, cols, data)
    }

    #[test]
    fn scalar_matches_reference() {
        let l = random_mat(4, 6, 1);
        let r = random_mat(3, 5, 2);
        let mut rng = XorShiftRng::new(3);
        let mut tile = vec![0.0; 6 * 5];
        rng.fill_normal(&mut tile);
        let mut out = vec![0.0; 4 * 3];
        let mut tmp = vec![0.0; 4 * 5];
        transform_tile_scalar(&l, &r, &tile, &mut out, &mut tmp);
        let want = reference(&l, &r, &tile);
        for (a, b) in out.iter().zip(&want) {
            assert!((a - b).abs() < 1e-4);
        }
    }

    #[test]
    fn lanes_match_scalar_per_lane() {
        let l = random_mat(6, 6, 4);
        let r = random_mat(6, 6, 5);
        let mut rng = XorShiftRng::new(6);
        // One tile of 6×6 pixels × 4 channels.
        let mut lanes = vec![F32x4::zero(); 36];
        for v in lanes.iter_mut() {
            *v = F32x4::from_array([rng.normal(), rng.normal(), rng.normal(), rng.normal()]);
        }
        let mut out = vec![F32x4::zero(); 36];
        let mut tmp = vec![F32x4::zero(); 36];
        transform_tile_lanes(&l, &r, &lanes, &mut out, &mut tmp);

        for lane in 0..4 {
            let tile: Vec<f32> = lanes.iter().map(|v| v.lane(lane)).collect();
            let want = reference(&l, &r, &tile);
            for (i, w) in want.iter().enumerate() {
                assert!(
                    (out[i].lane(lane) - w).abs() < 1e-3,
                    "lane {lane} elem {i}: {} vs {w}",
                    out[i].lane(lane)
                );
            }
        }
    }

    /// Transform-as-pack == generic transform followed by a PackedAWriter
    /// scatter, cell for cell (including zero-padded dead rows), on a shape
    /// with both a ragged channel group (k % 4 ≠ 0) and a short last panel
    /// (rows % MR ≠ 0).
    #[test]
    fn transform_and_pack_matches_generic_plus_writer() {
        use crate::gemm::pack::{packed_a_elems, PackedAWriter};
        // F(4×4,5×5) takes the generic dispatch path (no fast kernel).
        let plan = WinogradPlan::new(WinogradVariant::F4x4_5x5);
        let tiles = plan.h.t * plan.w.t;
        let (rows, k) = (7usize, 6usize);
        let a_stride = packed_a_elems(rows, k);
        let mut fused = vec![f32::NAN; tiles * a_stride];
        let mut manual = vec![f32::NAN; tiles * a_stride];
        for t in 0..tiles {
            PackedAWriter::new(&mut fused[t * a_stride..(t + 1) * a_stride], rows, k)
                .zero_pad_rows();
            PackedAWriter::new(&mut manual[t * a_stride..(t + 1) * a_stride], rows, k)
                .zero_pad_rows();
        }
        let mut rng = XorShiftRng::new(9);
        let fused_addr = fused.as_mut_ptr() as usize;
        let fused_len = fused.len();
        for row in 0..rows {
            for cg in (0..k).step_by(4) {
                let lanes = (k - cg).min(4);
                let d: Vec<F32x4> = (0..tiles)
                    .map(|_| {
                        F32x4::from_array([rng.normal(), rng.normal(), rng.normal(), rng.normal()])
                    })
                    .collect();
                let mut out = vec![F32x4::zero(); tiles];
                let mut tmp = vec![F32x4::zero(); tiles];
                transform_and_pack(
                    &plan, &d, &mut out, &mut tmp, fused_addr, fused_len, a_stride, k, row, cg,
                    lanes,
                );
                let mut out2 = vec![F32x4::zero(); tiles];
                let mut tmp2 = vec![F32x4::zero(); tiles];
                transform_tile_lanes(&plan.h.bt, &plan.w.bt, &d, &mut out2, &mut tmp2);
                for t in 0..tiles {
                    let mut w =
                        PackedAWriter::new(&mut manual[t * a_stride..(t + 1) * a_stride], rows, k);
                    w.write_lanes(row, cg, out2[t], lanes);
                }
            }
        }
        assert_eq!(fused, manual);
        assert!(fused.iter().all(|v| !v.is_nan()));
    }

    #[test]
    fn identity_axes_passthrough() {
        // L = 1×1 identity, R = 4×4 identity ⇒ out == tile (1×4).
        let l = MatF::identity1();
        let eye = MatF::new(
            4,
            4,
            (0..16).map(|i| if i % 5 == 0 { 1.0 } else { 0.0 }).collect(),
        );
        let tile = [
            F32x4::splat(1.0),
            F32x4::splat(2.0),
            F32x4::splat(3.0),
            F32x4::splat(4.0),
        ];
        let mut out = [F32x4::zero(); 4];
        let mut tmp = [F32x4::zero(); 4];
        transform_tile_lanes(&l, &eye, &tile, &mut out, &mut tmp);
        for (o, t) in out.iter().zip(&tile) {
            assert_eq!(o, t);
        }
    }
}
