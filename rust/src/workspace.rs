//! A reusable `f32` arena: the backing store for both of the engine's
//! per-thread memory pools.
//!
//! Every executor thread owns an arena **pair**, both pre-sized at prepare
//! time and both plain [`Workspace`]s:
//!
//! * **Conv scratch** — the fused Winograd pipeline borrows its
//!   padded-input staging buffer and packed-A block per layer
//!   (Winograd-domain C is never materialised; the staged ablation
//!   pipeline still borrows an A/C pair), the im2row baseline its staging
//!   buffer and patch matrix. Sized to the largest layer
//!   ([`crate::nn::PreparedModel::workspace_elems`]).
//! * **Planned activations** — the prepare-time planner
//!   ([`crate::nn::ActivationPlan`]) assigns every intermediate tensor an
//!   offset interval in a second arena sized to the plan's peak; the
//!   executor reads and writes borrowed windows of it instead of
//!   allocating per-layer output tensors.
//!
//! Allocating any of this per call is exactly the working-set churn the
//! paper's memory-budget discussion warns about; with both arenas warm, a
//! whole steady-state inference — transforms, GEMMs, epilogues, pooling,
//! FC, softmax — performs **zero heap allocations**, end to end.
//!
//! The arena is deliberately dumb: one flat buffer, borrowed as one or two
//! disjoint slices per layer, fully overwritten by each user (no zeroing on
//! reuse — every borrower writes its whole slice before reading). The
//! [`grow_count`](Workspace::grow_count) statistic exists so tests can
//! assert the no-regrowth property instead of trusting it.
//!
//! ```
//! use winoconv::workspace::Workspace;
//! let mut ws = Workspace::new();
//! let (a, c) = ws.split2(8, 4);
//! a[0] = 1.0;
//! c[3] = 2.0;
//! assert_eq!(ws.grow_count(), 1); // first borrow grew the empty arena
//! let _ = ws.split2(8, 4);
//! assert_eq!(ws.grow_count(), 1); // reuse does not grow
//! ```

/// `f32` elements needed to hold `bytes` bytes of non-f32 scratch —
/// the mixed-dtype sizing rule of the arena. The quantized engines
/// ([`crate::quant`]) borrow f32 slices and reinterpret them as byte
/// buffers (u8 staging, i8 panels), so their byte budgets must be ceiled
/// into 4-byte units **before** they are summed into `workspace_elems()`;
/// flooring would undersize the arena and break the grow-count = 0
/// invariant on quantized walks.
pub fn elems_for_bytes(bytes: usize) -> usize {
    bytes.div_ceil(std::mem::size_of::<f32>())
}

/// A growable flat `f32` arena handed out as per-layer scratch slices.
#[derive(Debug, Default)]
pub struct Workspace {
    buf: Vec<f32>,
    grows: usize,
    high_water: usize,
}

impl Workspace {
    /// An empty arena; the first borrow sizes it.
    pub fn new() -> Workspace {
        Workspace::default()
    }

    /// An arena pre-sized to `elems` `f32` values, so borrows up to that
    /// size never grow (and [`grow_count`](Self::grow_count) stays 0).
    pub fn with_capacity(elems: usize) -> Workspace {
        Workspace {
            buf: vec![0.0; elems],
            grows: 0,
            high_water: 0,
        }
    }

    /// Current arena size in elements.
    pub fn capacity(&self) -> usize {
        self.buf.len()
    }

    /// Current arena size in bytes.
    pub fn bytes(&self) -> usize {
        self.buf.len() * std::mem::size_of::<f32>()
    }

    /// How many times a borrow had to grow the buffer. A steady-state hot
    /// loop must keep this constant after the first pass (zero when the
    /// arena was pre-sized with [`with_capacity`](Self::with_capacity)).
    pub fn grow_count(&self) -> usize {
        self.grows
    }

    /// Largest borrow observed, in elements.
    pub fn high_water_elems(&self) -> usize {
        self.high_water
    }

    fn ensure(&mut self, elems: usize) {
        self.high_water = self.high_water.max(elems);
        if self.buf.len() < elems {
            self.grows += 1;
            // statcheck: allow(no-alloc): counted grow path; ci.sh pins grow_count to 0.
            self.buf.resize(elems, 0.0);
        }
    }

    /// Borrow one scratch slice of `elems` values. Contents are
    /// unspecified — the borrower must write before reading.
    pub fn take(&mut self, elems: usize) -> &mut [f32] {
        self.ensure(elems);
        &mut self.buf[..elems]
    }

    /// Borrow two disjoint scratch slices of `a` and `b` values (the
    /// Winograd A/C block pair). Contents are unspecified.
    pub fn split2(&mut self, a: usize, b: usize) -> (&mut [f32], &mut [f32]) {
        self.ensure(a + b);
        let (x, rest) = self.buf.split_at_mut(a);
        (x, &mut rest[..b])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn grows_once_then_reuses() {
        let mut ws = Workspace::new();
        assert_eq!(ws.capacity(), 0);
        {
            let s = ws.take(100);
            assert_eq!(s.len(), 100);
        }
        assert_eq!(ws.grow_count(), 1);
        for _ in 0..10 {
            let _ = ws.take(100);
        }
        assert_eq!(ws.grow_count(), 1);
        assert_eq!(ws.capacity(), 100);
        // A bigger request grows again; smaller ones never shrink it.
        let _ = ws.take(150);
        assert_eq!(ws.grow_count(), 2);
        let _ = ws.take(10);
        assert_eq!(ws.capacity(), 150);
        assert_eq!(ws.high_water_elems(), 150);
    }

    #[test]
    fn presized_never_grows() {
        let mut ws = Workspace::with_capacity(64);
        for n in [1usize, 32, 64] {
            let _ = ws.split2(n / 2, n - n / 2);
        }
        assert_eq!(ws.grow_count(), 0);
        assert_eq!(ws.bytes(), 64 * 4);
    }

    #[test]
    fn split2_slices_are_disjoint_and_sized() {
        let mut ws = Workspace::new();
        let (a, b) = ws.split2(5, 7);
        assert_eq!(a.len(), 5);
        assert_eq!(b.len(), 7);
        for v in a.iter_mut() {
            *v = 1.0;
        }
        for v in b.iter_mut() {
            *v = 2.0;
        }
        // Re-borrow and check the writes landed in disjoint regions.
        let (a, b) = ws.split2(5, 7);
        assert!(a.iter().all(|&v| v == 1.0));
        assert!(b.iter().all(|&v| v == 2.0));
    }

    #[test]
    fn elems_for_bytes_rounds_up() {
        assert_eq!(elems_for_bytes(0), 0);
        assert_eq!(elems_for_bytes(1), 1);
        assert_eq!(elems_for_bytes(4), 1);
        assert_eq!(elems_for_bytes(5), 2);
        assert_eq!(elems_for_bytes(8), 2);
        assert_eq!(elems_for_bytes(1023), 256);
    }

    #[test]
    fn zero_sized_borrows_are_fine() {
        let mut ws = Workspace::new();
        let (a, b) = ws.split2(0, 0);
        assert!(a.is_empty() && b.is_empty());
        assert_eq!(ws.grow_count(), 0);
    }
}
