//! GoogleNet / Inception-v1 (Szegedy et al. 2015), main branch (auxiliary
//! classifiers are inference-time no-ops and omitted).
//!
//! Each inception module mixes 1×1, 3×3 and 5×5 convs — the paper's Table 2
//! measures both the 3×3 (2.6× avg, **4.1× peak** — the headline) and 5×5
//! (2.3× avg) layers of this network.

use super::Builder;
use crate::nn::{Graph, NodeId};
use crate::Result;

/// Inception module: four parallel branches concatenated.
/// `(b1, b3r, b3, b5r, b5, pp)` = 1×1, 3×3-reduce, 3×3, 5×5-reduce, 5×5,
/// pool-proj widths, as in Table 1 of the GoogleNet paper.
#[allow(clippy::too_many_arguments)]
fn inception(
    b: &mut Builder,
    name: &str,
    from: NodeId,
    cin: usize,
    b1: usize,
    b3r: usize,
    b3: usize,
    b5r: usize,
    b5: usize,
    pp: usize,
) -> NodeId {
    let br1 = b.conv(&format!("{name}/1x1"), from, cin, b1, (1, 1), (1, 1), (0, 0));
    let r3 = b.conv(&format!("{name}/3x3_reduce"), from, cin, b3r, (1, 1), (1, 1), (0, 0));
    let br3 = b.conv(&format!("{name}/3x3"), r3, b3r, b3, (3, 3), (1, 1), (1, 1));
    let r5 = b.conv(&format!("{name}/5x5_reduce"), from, cin, b5r, (1, 1), (1, 1), (0, 0));
    let br5 = b.conv(&format!("{name}/5x5"), r5, b5r, b5, (5, 5), (1, 1), (2, 2));
    let mp = b.maxpool(&format!("{name}/pool"), from, 3, 1, 1, false);
    let brp = b.conv(&format!("{name}/pool_proj"), mp, cin, pp, (1, 1), (1, 1), (0, 0));
    b.concat(&format!("{name}/output"), &[br1, br3, br5, brp])
}

/// Build GoogleNet (224×224×3 → 1000 classes).
pub fn build(seed: u64) -> Result<Graph> {
    let (mut b, input) = Builder::new(seed);
    // Stem.
    let c1 = b.conv("conv1/7x7_s2", input, 3, 64, (7, 7), (2, 2), (3, 3));
    let p1 = b.maxpool("pool1/3x3_s2", c1, 3, 2, 0, true);
    let n1 = b.lrn("pool1/norm1", p1);
    let c2r = b.conv("conv2/3x3_reduce", n1, 64, 64, (1, 1), (1, 1), (0, 0));
    let c2 = b.conv("conv2/3x3", c2r, 64, 192, (3, 3), (1, 1), (1, 1));
    let n2 = b.lrn("conv2/norm2", c2);
    let p2 = b.maxpool("pool2/3x3_s2", n2, 3, 2, 0, true);
    // Inception stacks (widths from the GoogleNet paper's Table 1).
    let i3a = inception(&mut b, "inception_3a", p2, 192, 64, 96, 128, 16, 32, 32); // → 256
    let i3b = inception(&mut b, "inception_3b", i3a, 256, 128, 128, 192, 32, 96, 64); // → 480
    let p3 = b.maxpool("pool3/3x3_s2", i3b, 3, 2, 0, true);
    let i4a = inception(&mut b, "inception_4a", p3, 480, 192, 96, 208, 16, 48, 64); // → 512
    let i4b = inception(&mut b, "inception_4b", i4a, 512, 160, 112, 224, 24, 64, 64); // → 512
    let i4c = inception(&mut b, "inception_4c", i4b, 512, 128, 128, 256, 24, 64, 64); // → 512
    let i4d = inception(&mut b, "inception_4d", i4c, 512, 112, 144, 288, 32, 64, 64); // → 528
    let i4e = inception(&mut b, "inception_4e", i4d, 528, 256, 160, 320, 32, 128, 128); // → 832
    let p4 = b.maxpool("pool4/3x3_s2", i4e, 3, 2, 0, true);
    let i5a = inception(&mut b, "inception_5a", p4, 832, 256, 160, 320, 32, 128, 128); // → 832
    let i5b = inception(&mut b, "inception_5b", i5a, 832, 384, 192, 384, 48, 128, 128); // → 1024
    let gap = b.gap("pool5/7x7_s1", i5b);
    let fc = b.fc("loss3/classifier", gap, 1024, 1000, false);
    b.softmax("prob", fc);
    Ok(b.g)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::Op;

    #[test]
    fn structure() {
        let g = build(1).unwrap();
        // Stem 3 convs + 9 modules × 6 convs = 57 convs.
        assert_eq!(g.conv_count(), 57);
        let shapes = g.infer_shapes(&[1, 224, 224, 3]).unwrap();
        assert_eq!(shapes.last().unwrap(), &vec![1, 1000]);
    }

    #[test]
    fn module_output_widths() {
        let g = build(1).unwrap();
        let shapes = g.infer_shapes(&[1, 224, 224, 3]).unwrap();
        for (name, c) in [
            ("inception_3a/output", 256),
            ("inception_3b/output", 480),
            ("inception_4e/output", 832),
            ("inception_5b/output", 1024),
        ] {
            let idx = g.nodes.iter().position(|n| n.name == name).unwrap();
            assert_eq!(shapes[idx][3], c, "{name}");
        }
    }

    #[test]
    fn has_both_3x3_and_5x5_fast_layers() {
        let g = build(1).unwrap();
        let mut k33 = 0;
        let mut k55 = 0;
        for n in &g.nodes {
            if let Op::Conv { desc, .. } = &n.op {
                match desc.kernel {
                    (3, 3) if desc.stride == (1, 1) => k33 += 1,
                    (5, 5) => k55 += 1,
                    _ => {}
                }
            }
        }
        assert_eq!(k33, 10); // conv2/3x3 + 9 modules
        assert_eq!(k55, 9);
    }
}
