//! Inception-v3 (Szegedy et al. 2016), inference branch.
//!
//! The interesting model for the paper's **1-D Cook-Toom** variants: the
//! 17×17 modules factorise 7×7 convolutions into `1×7`/`7×1` pairs (Table 2
//! rows "1×7" and "7×1", ~2.0–2.1×), the 8×8 modules use `1×3`/`3×1`
//! splits, and the 35×35 modules carry the 5×5 layers (2.7× avg).

use super::Builder;
use crate::nn::{Graph, NodeId};
use crate::Result;

/// avgpool(3×3, s1, p1) → 1×1 projection, the pool branch of every module.
fn pool_proj(b: &mut Builder, name: &str, from: NodeId, cin: usize, cout: usize) -> NodeId {
    let p = b.avgpool(&format!("{name}/pool"), from, 3, 1, 1);
    b.conv(&format!("{name}/pool_proj"), p, cin, cout, (1, 1), (1, 1), (0, 0))
}

/// Inception-A (35×35): 1×1 / 5×5 / double-3×3 / pool branches.
fn module_a(b: &mut Builder, name: &str, from: NodeId, cin: usize, pp: usize) -> NodeId {
    let b1 = b.conv(&format!("{name}/1x1"), from, cin, 64, (1, 1), (1, 1), (0, 0));
    let r5 = b.conv(&format!("{name}/5x5_reduce"), from, cin, 48, (1, 1), (1, 1), (0, 0));
    let b5 = b.conv(&format!("{name}/5x5"), r5, 48, 64, (5, 5), (1, 1), (2, 2));
    let r3 = b.conv(&format!("{name}/3x3dbl_reduce"), from, cin, 64, (1, 1), (1, 1), (0, 0));
    let d1 = b.conv(&format!("{name}/3x3dbl_1"), r3, 64, 96, (3, 3), (1, 1), (1, 1));
    let d2 = b.conv(&format!("{name}/3x3dbl_2"), d1, 96, 96, (3, 3), (1, 1), (1, 1));
    let bp = pool_proj(b, name, from, cin, pp);
    b.concat(&format!("{name}/output"), &[b1, b5, d2, bp])
}

/// Reduction-A (35→17).
fn reduction_a(b: &mut Builder, name: &str, from: NodeId, cin: usize) -> NodeId {
    let b3 = b.conv(&format!("{name}/3x3"), from, cin, 384, (3, 3), (2, 2), (0, 0));
    let r = b.conv(&format!("{name}/3x3dbl_reduce"), from, cin, 64, (1, 1), (1, 1), (0, 0));
    let d1 = b.conv(&format!("{name}/3x3dbl_1"), r, 64, 96, (3, 3), (1, 1), (1, 1));
    let d2 = b.conv(&format!("{name}/3x3dbl_2"), d1, 96, 96, (3, 3), (2, 2), (0, 0));
    let mp = b.maxpool(&format!("{name}/pool"), from, 3, 2, 0, false);
    b.concat(&format!("{name}/output"), &[b3, d2, mp])
}

/// Inception-B (17×17): factorised 7×7 via `1×7`/`7×1` chains.
fn module_b(b: &mut Builder, name: &str, from: NodeId, cin: usize, c7: usize) -> NodeId {
    let b1 = b.conv(&format!("{name}/1x1"), from, cin, 192, (1, 1), (1, 1), (0, 0));
    // 7×7 branch: 1×1 → 1×7 → 7×1.
    let r7 = b.conv(&format!("{name}/7x7_reduce"), from, cin, c7, (1, 1), (1, 1), (0, 0));
    let a = b.conv(&format!("{name}/1x7"), r7, c7, c7, (1, 7), (1, 1), (0, 3));
    let b7 = b.conv(&format!("{name}/7x1"), a, c7, 192, (7, 1), (1, 1), (3, 0));
    // Double 7×7 branch: 1×1 → 7×1 → 1×7 → 7×1 → 1×7.
    let rd = b.conv(&format!("{name}/7x7dbl_reduce"), from, cin, c7, (1, 1), (1, 1), (0, 0));
    let d1 = b.conv(&format!("{name}/7x7dbl_1"), rd, c7, c7, (7, 1), (1, 1), (3, 0));
    let d2 = b.conv(&format!("{name}/7x7dbl_2"), d1, c7, c7, (1, 7), (1, 1), (0, 3));
    let d3 = b.conv(&format!("{name}/7x7dbl_3"), d2, c7, c7, (7, 1), (1, 1), (3, 0));
    let d4 = b.conv(&format!("{name}/7x7dbl_4"), d3, c7, 192, (1, 7), (1, 1), (0, 3));
    let bp = pool_proj(b, name, from, cin, 192);
    b.concat(&format!("{name}/output"), &[b1, b7, d4, bp])
}

/// Reduction-B (17→8).
fn reduction_b(b: &mut Builder, name: &str, from: NodeId, cin: usize) -> NodeId {
    let r3 = b.conv(&format!("{name}/3x3_reduce"), from, cin, 192, (1, 1), (1, 1), (0, 0));
    let b3 = b.conv(&format!("{name}/3x3"), r3, 192, 320, (3, 3), (2, 2), (0, 0));
    let r7 = b.conv(&format!("{name}/7x7x3_reduce"), from, cin, 192, (1, 1), (1, 1), (0, 0));
    let a = b.conv(&format!("{name}/1x7"), r7, 192, 192, (1, 7), (1, 1), (0, 3));
    let c = b.conv(&format!("{name}/7x1"), a, 192, 192, (7, 1), (1, 1), (3, 0));
    let d = b.conv(&format!("{name}/3x3_2"), c, 192, 192, (3, 3), (2, 2), (0, 0));
    let mp = b.maxpool(&format!("{name}/pool"), from, 3, 2, 0, false);
    b.concat(&format!("{name}/output"), &[b3, d, mp])
}

/// Inception-C (8×8): `1×3`/`3×1` output splits.
fn module_c(b: &mut Builder, name: &str, from: NodeId, cin: usize) -> NodeId {
    let b1 = b.conv(&format!("{name}/1x1"), from, cin, 320, (1, 1), (1, 1), (0, 0));
    let r3 = b.conv(&format!("{name}/3x3_reduce"), from, cin, 384, (1, 1), (1, 1), (0, 0));
    let s1 = b.conv(&format!("{name}/3x3_a"), r3, 384, 384, (1, 3), (1, 1), (0, 1));
    let s2 = b.conv(&format!("{name}/3x3_b"), r3, 384, 384, (3, 1), (1, 1), (1, 0));
    let rd = b.conv(&format!("{name}/3x3dbl_reduce"), from, cin, 448, (1, 1), (1, 1), (0, 0));
    let d0 = b.conv(&format!("{name}/3x3dbl_1"), rd, 448, 384, (3, 3), (1, 1), (1, 1));
    let d1 = b.conv(&format!("{name}/3x3dbl_a"), d0, 384, 384, (1, 3), (1, 1), (0, 1));
    let d2 = b.conv(&format!("{name}/3x3dbl_b"), d0, 384, 384, (3, 1), (1, 1), (1, 0));
    let bp = pool_proj(b, name, from, cin, 192);
    b.concat(&format!("{name}/output"), &[b1, s1, s2, d1, d2, bp])
}

/// Build Inception-v3 (299×299×3 → 1000 classes).
pub fn build(seed: u64) -> Result<Graph> {
    let (mut b, input) = Builder::new(seed);
    // Stem: 299 → 35×35×192.
    let c1 = b.conv("conv1_3x3_s2", input, 3, 32, (3, 3), (2, 2), (0, 0)); // 149
    let c2 = b.conv("conv2_3x3", c1, 32, 32, (3, 3), (1, 1), (0, 0)); // 147
    let c3 = b.conv("conv3_3x3", c2, 32, 64, (3, 3), (1, 1), (1, 1)); // 147
    let p1 = b.maxpool("pool1_3x3_s2", c3, 3, 2, 0, false); // 73
    let c4 = b.conv("conv4_1x1", p1, 64, 80, (1, 1), (1, 1), (0, 0));
    let c5 = b.conv("conv5_3x3", c4, 80, 192, (3, 3), (1, 1), (0, 0)); // 71
    let p2 = b.maxpool("pool2_3x3_s2", c5, 3, 2, 0, false); // 35
    // 35×35 stack.
    let m5b = module_a(&mut b, "mixed_5b", p2, 192, 32); // 256
    let m5c = module_a(&mut b, "mixed_5c", m5b, 256, 64); // 288
    let m5d = module_a(&mut b, "mixed_5d", m5c, 288, 64); // 288
    let m6a = reduction_a(&mut b, "mixed_6a", m5d, 288); // 768 @ 17
    // 17×17 stack.
    let m6b = module_b(&mut b, "mixed_6b", m6a, 768, 128);
    let m6c = module_b(&mut b, "mixed_6c", m6b, 768, 160);
    let m6d = module_b(&mut b, "mixed_6d", m6c, 768, 160);
    let m6e = module_b(&mut b, "mixed_6e", m6d, 768, 192);
    let m7a = reduction_b(&mut b, "mixed_7a", m6e, 768); // 1280 @ 8
    // 8×8 stack.
    let m7b = module_c(&mut b, "mixed_7b", m7a, 1280); // 2048
    let m7c = module_c(&mut b, "mixed_7c", m7b, 2048); // 2048
    let gap = b.gap("pool3", m7c);
    let fc = b.fc("fc", gap, 2048, 1000, false);
    b.softmax("prob", fc);
    Ok(b.g)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::Op;

    #[test]
    fn structure_and_output() {
        let g = build(1).unwrap();
        // Stem 5 + 3×A(7) + redA(4) + 4×B(10) + redB(6) + 2×C(9) = 94 convs.
        assert_eq!(g.conv_count(), 94);
        let shapes = g.infer_shapes(&[1, 299, 299, 3]).unwrap();
        assert_eq!(shapes.last().unwrap(), &vec![1, 1000]);
    }

    #[test]
    fn stage_spatial_sizes() {
        let g = build(1).unwrap();
        let shapes = g.infer_shapes(&[1, 299, 299, 3]).unwrap();
        for (name, hw, c) in [
            ("mixed_5b/output", 35, 256),
            ("mixed_5d/output", 35, 288),
            ("mixed_6a/output", 17, 768),
            ("mixed_6e/output", 17, 768),
            ("mixed_7a/output", 8, 1280),
            ("mixed_7c/output", 8, 2048),
        ] {
            let idx = g.nodes.iter().position(|n| n.name == name).unwrap();
            assert_eq!(shapes[idx][1], hw, "{name} height");
            assert_eq!(shapes[idx][3], c, "{name} channels");
        }
    }

    #[test]
    fn has_all_four_fast_layer_types() {
        let g = build(1).unwrap();
        let mut counts = std::collections::HashMap::new();
        for n in &g.nodes {
            if let Op::Conv { desc, .. } = &n.op {
                if desc.stride == (1, 1) {
                    *counts.entry(desc.kernel).or_insert(0usize) += 1;
                }
            }
        }
        assert!(counts[&(3, 3)] >= 8, "3x3: {:?}", counts.get(&(3, 3)));
        assert_eq!(counts[&(5, 5)], 3);
        assert_eq!(counts[&(1, 7)], 13); // 4 modules ×3 + reduction-B
        assert_eq!(counts[&(7, 1)], 13);
        assert_eq!(counts[&(1, 3)], 4);
        assert_eq!(counts[&(3, 1)], 4);
    }
}
