//! MobileNetV1 (Howard et al. 2017) and MobileNetV2 (Sandler et al. 2018)
//! — the depthwise-separable workload class the direct depthwise engine
//! ([`crate::conv::depthwise`]) exists for.
//!
//! Both networks interleave 3×3 **depthwise** convolutions (one filter per
//! channel — `groups == cin == cout`, bound to the register-tiled direct
//! engine by the selector) with 1×1 **pointwise** convolutions (pure
//! channel mixing — on the ours scheme these bind to the zero-copy direct
//! pointwise engine ([`crate::conv::pointwise`]); the baseline scheme keeps
//! the bit-identical im2row/GEMM path). All hidden activations are the
//! ReLU6 clamp the TF reference models train with, fused through the conv
//! epilogues; MobileNetV2's projection layers are linear (no activation)
//! and its stride-1 equal-width bottlenecks carry an elementwise residual
//! ([`crate::nn::Op::Add`]) with the conv operand first, so the prepared
//! model collapses `project → add` into one fused-residual pointwise GEMM.
//!
//! Note on the benchmark schemes: neither network has a single
//! Winograd-suitable layer (the only dense 3×3 conv is the stride-2 stem),
//! so the scheme split is pointwise-engine-vs-im2row for the 1×1s — bit-
//! identical outputs either way; the timing comparisons for this class are
//! `benches/ablation_depthwise.rs` and `benches/ablation_pointwise.rs`,
//! not Table 1's Winograd split.

use super::Builder;
use crate::conv::Activation;
use crate::nn::{Graph, NodeId};
use crate::Result;

/// One depthwise-separable block: dw 3×3 (stride `s`, ReLU6) → pw 1×1
/// (ReLU6). Returns the pointwise output.
fn separable(
    b: &mut Builder,
    name: &str,
    from: NodeId,
    cin: usize,
    cout: usize,
    stride: usize,
) -> NodeId {
    let dw = b.dwconv(&format!("{name}/dw"), from, cin, stride, Activation::Relu6);
    b.conv_act(
        &format!("{name}/pw"),
        dw,
        cin,
        cout,
        (1, 1),
        (1, 1),
        (0, 0),
        Activation::Relu6,
    )
}

/// Build MobileNetV1 at width 1.0 (224×224×3 → 1000 classes): a 3×3/2 stem
/// then 13 depthwise-separable blocks, GAP, FC.
pub fn build_v1(seed: u64) -> Result<Graph> {
    let (mut b, input) = Builder::new(seed);
    let c1 = b.conv_act("conv1", input, 3, 32, (3, 3), (2, 2), (1, 1), Activation::Relu6);
    // (cin, cout, stride) per separable block, Table 1 of the paper.
    let blocks: [(usize, usize, usize); 13] = [
        (32, 64, 1),
        (64, 128, 2),
        (128, 128, 1),
        (128, 256, 2),
        (256, 256, 1),
        (256, 512, 2),
        (512, 512, 1),
        (512, 512, 1),
        (512, 512, 1),
        (512, 512, 1),
        (512, 512, 1),
        (512, 1024, 2),
        (1024, 1024, 1),
    ];
    let mut prev = c1;
    for (i, &(cin, cout, s)) in blocks.iter().enumerate() {
        prev = separable(&mut b, &format!("sep{}", i + 2), prev, cin, cout, s);
    }
    let gap = b.gap("gap", prev);
    let fc = b.fc("fc", gap, 1024, 1000, false);
    b.softmax("prob", fc);
    Ok(b.g)
}

/// One MobileNetV2 inverted-residual bottleneck: pw-expand (×`t`, ReLU6,
/// skipped when `t == 1`) → dw 3×3 (stride `s`, ReLU6) → pw-linear
/// projection; plus a residual add when the block keeps shape.
fn bottleneck(
    b: &mut Builder,
    name: &str,
    from: NodeId,
    cin: usize,
    cout: usize,
    stride: usize,
    t: usize,
) -> NodeId {
    let hidden = cin * t;
    let x = if t == 1 {
        from
    } else {
        b.conv_act(
            &format!("{name}/expand"),
            from,
            cin,
            hidden,
            (1, 1),
            (1, 1),
            (0, 0),
            Activation::Relu6,
        )
    };
    let dw = b.dwconv(&format!("{name}/dw"), x, hidden, stride, Activation::Relu6);
    let proj = b.conv_act(
        &format!("{name}/project"),
        dw,
        hidden,
        cout,
        (1, 1),
        (1, 1),
        (0, 0),
        Activation::None,
    );
    if stride == 1 && cin == cout {
        // Conv operand first (the zoo residual convention): the prepared
        // model fuses this linear projection + add into one pointwise GEMM
        // with a residual epilogue on the ours scheme.
        b.add(&format!("{name}/add"), proj, from)
    } else {
        proj
    }
}

/// Build MobileNetV2 at width 1.0 (224×224×3 → 1000 classes): stem, 17
/// inverted-residual bottlenecks per the paper's Table 2
/// `(t, c, n, s)` rows, the 1×1×1280 head, GAP, FC.
pub fn build_v2(seed: u64) -> Result<Graph> {
    let (mut b, input) = Builder::new(seed);
    let mut prev = b.conv_act("conv1", input, 3, 32, (3, 3), (2, 2), (1, 1), Activation::Relu6);
    // (expansion t, output channels c, repeats n, first-block stride s).
    let rows: [(usize, usize, usize, usize); 7] = [
        (1, 16, 1, 1),
        (6, 24, 2, 2),
        (6, 32, 3, 2),
        (6, 64, 4, 2),
        (6, 96, 3, 1),
        (6, 160, 3, 2),
        (6, 320, 1, 1),
    ];
    let mut cin = 32;
    let mut idx = 0;
    for &(t, c, n, s) in rows.iter() {
        for rep in 0..n {
            let stride = if rep == 0 { s } else { 1 };
            idx += 1;
            prev = bottleneck(&mut b, &format!("block{idx}"), prev, cin, c, stride, t);
            cin = c;
        }
    }
    let head = b.conv_act("conv_head", prev, 320, 1280, (1, 1), (1, 1), (0, 0), Activation::Relu6);
    let gap = b.gap("gap", head);
    let fc = b.fc("fc", gap, 1280, 1000, false);
    b.softmax("prob", fc);
    Ok(b.g)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::conv::select::is_winograd_suitable;
    use crate::nn::Op;

    #[test]
    fn v1_structure() {
        let g = build_v1(1).unwrap();
        // Stem + 13 × (dw + pw) = 27 convs.
        assert_eq!(g.conv_count(), 27);
        let shapes = g.infer_shapes(&[1, 224, 224, 3]).unwrap();
        assert_eq!(shapes.last().unwrap(), &vec![1, 1000]);
        // The canonical spatial schedule: 224 → 112 → 56 → 28 → 14 → 7.
        let idx = g.nodes.iter().position(|n| n.name == "sep14/pw").unwrap();
        assert_eq!(shapes[idx], vec![1, 7, 7, 1024]);
        // 13 depthwise + zero Winograd-suitable layers.
        let mut dw = 0;
        for n in &g.nodes {
            if let Op::Conv { desc, .. } = &n.op {
                if desc.groups > 1 {
                    assert_eq!(desc.groups, desc.cin);
                    assert_eq!(desc.groups, desc.cout);
                    dw += 1;
                }
                assert!(!is_winograd_suitable(desc.kernel, desc.stride, desc.groups));
            }
        }
        assert_eq!(dw, 13);
    }

    #[test]
    fn v2_structure() {
        let g = build_v2(1).unwrap();
        let shapes = g.infer_shapes(&[1, 224, 224, 3]).unwrap();
        assert_eq!(shapes.last().unwrap(), &vec![1, 1000]);
        // 17 bottlenecks ⇒ 17 depthwise convs; 10 of them residual.
        let dw = g
            .nodes
            .iter()
            .filter(|n| matches!(&n.op, Op::Conv { desc, .. } if desc.groups > 1))
            .count();
        assert_eq!(dw, 17);
        let adds = g.nodes.iter().filter(|n| matches!(n.op, Op::Add)).count();
        assert_eq!(adds, 10);
        // Head sees 7×7×320 → 1280.
        let idx = g.nodes.iter().position(|n| n.name == "conv_head").unwrap();
        assert_eq!(shapes[idx], vec![1, 7, 7, 1280]);
        // Every hidden conv activation is ReLU6 or linear (projections).
        for n in &g.nodes {
            if let Op::Conv { act, .. } = &n.op {
                assert!(
                    *act == crate::conv::Activation::Relu6
                        || *act == crate::conv::Activation::None,
                    "{}: unexpected activation {act}",
                    n.name
                );
            }
        }
    }
}
