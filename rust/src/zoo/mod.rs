//! The evaluated CNNs as [`Graph`]s with deterministic synthetic weights
//! (runtime of dense fp32 conv is data-independent, so synthetic weights
//! preserve every timing property — see DESIGN.md §Substitutions): the five
//! networks of the paper's §3 (VGG-16, VGG-19, GoogleNet/Inception-v1,
//! Inception-v3, SqueezeNet v1.0) plus the depthwise-separable MobileNetV1
//! and MobileNetV2 — the workload class the direct depthwise engine
//! ([`crate::conv::depthwise`]) exists for — and the residual ResNet-18 /
//! ResNet-50, whose 1×1-heavy bottlenecks exercise the zero-copy pointwise
//! engine ([`crate::conv::pointwise`]) and its fused residual epilogue.
//!
//! Architectures follow the original papers' layer tables; layer names match
//! the conventions used in each paper so Table 2 rows are recognisable.

pub mod vgg;
pub mod squeezenet;
pub mod googlenet;
pub mod inception_v3;
pub mod mobilenet;
pub mod resnet;

use crate::conv::{Activation, Conv2d};
use crate::nn::{Graph, NodeId, Op};
use crate::tensor::Tensor;
use crate::Result;

/// The evaluated model set.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum ModelKind {
    /// VGG-16 (224×224 input).
    Vgg16,
    /// VGG-19 (224×224 input).
    Vgg19,
    /// GoogleNet / Inception-v1 (224×224 input).
    GoogleNet,
    /// Inception-v3 (299×299 input).
    InceptionV3,
    /// SqueezeNet v1.0 (224×224 input).
    SqueezeNet,
    /// MobileNetV1 (224×224 input, depthwise-separable).
    MobileNetV1,
    /// MobileNetV2 (224×224 input, inverted residuals + ReLU6).
    MobileNetV2,
    /// ResNet-18 (224×224 input, basic residual blocks).
    ResNet18,
    /// ResNet-50 (224×224 input, 1×1-heavy bottleneck blocks).
    ResNet50,
}

impl ModelKind {
    /// Every model: the paper's five in table order, then the MobileNets
    /// and the ResNets.
    pub const ALL: [ModelKind; 9] = [
        ModelKind::Vgg16,
        ModelKind::Vgg19,
        ModelKind::GoogleNet,
        ModelKind::InceptionV3,
        ModelKind::SqueezeNet,
        ModelKind::MobileNetV1,
        ModelKind::MobileNetV2,
        ModelKind::ResNet18,
        ModelKind::ResNet50,
    ];

    /// Canonical lowercase name (CLI `--model` values).
    pub fn name(&self) -> &'static str {
        match self {
            ModelKind::Vgg16 => "vgg16",
            ModelKind::Vgg19 => "vgg19",
            ModelKind::GoogleNet => "googlenet",
            ModelKind::InceptionV3 => "inception-v3",
            ModelKind::SqueezeNet => "squeezenet",
            ModelKind::MobileNetV1 => "mobilenet-v1",
            ModelKind::MobileNetV2 => "mobilenet-v2",
            ModelKind::ResNet18 => "resnet-18",
            ModelKind::ResNet50 => "resnet-50",
        }
    }

    /// Display name as the papers' tables print it.
    pub fn display(&self) -> &'static str {
        match self {
            ModelKind::Vgg16 => "VGG-16",
            ModelKind::Vgg19 => "VGG-19",
            ModelKind::GoogleNet => "GoogleNet",
            ModelKind::InceptionV3 => "Inception-v3",
            ModelKind::SqueezeNet => "SqueezeNet",
            ModelKind::MobileNetV1 => "MobileNetV1",
            ModelKind::MobileNetV2 => "MobileNetV2",
            ModelKind::ResNet18 => "ResNet-18",
            ModelKind::ResNet50 => "ResNet-50",
        }
    }

    /// Parse a CLI name.
    pub fn parse(s: &str) -> Option<ModelKind> {
        match s.to_ascii_lowercase().as_str() {
            "vgg16" | "vgg-16" => Some(ModelKind::Vgg16),
            "vgg19" | "vgg-19" => Some(ModelKind::Vgg19),
            "googlenet" | "inception-v1" => Some(ModelKind::GoogleNet),
            "inception-v3" | "inceptionv3" | "inception3" => Some(ModelKind::InceptionV3),
            "squeezenet" => Some(ModelKind::SqueezeNet),
            "mobilenet-v1" | "mobilenetv1" | "mobilenet1" | "mobilenet" => {
                Some(ModelKind::MobileNetV1)
            }
            "mobilenet-v2" | "mobilenetv2" | "mobilenet2" => Some(ModelKind::MobileNetV2),
            "resnet-18" | "resnet18" => Some(ModelKind::ResNet18),
            "resnet-50" | "resnet50" => Some(ModelKind::ResNet50),
            // Bare "resnet" stays unparsed: there is no canonical depth.
            _ => None,
        }
    }

    /// NHWC input shape at batch size `n`.
    pub fn input_shape(&self, n: usize) -> Vec<usize> {
        match self {
            ModelKind::InceptionV3 => vec![n, 299, 299, 3],
            _ => vec![n, 224, 224, 3],
        }
    }

    /// The models the quantized (int8) evaluation covers: the mobile-CPU
    /// targets whose layer mix (depthwise / pointwise / small dense 3×3)
    /// maps 1:1 onto the int8 engine set. The legacy large nets stay
    /// f32-only in the tables — their 5×5/7×7/1×7 layers are exactly the
    /// Winograd-suitable shapes whose int8 twin would be plain im2row.
    pub fn quantizable(&self) -> bool {
        matches!(
            self,
            ModelKind::MobileNetV1 | ModelKind::MobileNetV2 | ModelKind::ResNet18
        )
    }

    /// Build the graph with deterministic weights derived from `seed`.
    pub fn build(&self, seed: u64) -> Result<Graph> {
        match self {
            ModelKind::Vgg16 => vgg::build(16, seed),
            ModelKind::Vgg19 => vgg::build(19, seed),
            ModelKind::GoogleNet => googlenet::build(seed),
            ModelKind::InceptionV3 => inception_v3::build(seed),
            ModelKind::SqueezeNet => squeezenet::build(seed),
            ModelKind::MobileNetV1 => mobilenet::build_v1(seed),
            ModelKind::MobileNetV2 => mobilenet::build_v2(seed),
            ModelKind::ResNet18 => resnet::build_18(seed),
            ModelKind::ResNet50 => resnet::build_50(seed),
        }
    }
}

impl std::fmt::Display for ModelKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}", self.display())
    }
}

/// Shared builder: wraps a [`Graph`] and hands out deterministic weights
/// from an internal seed counter.
pub(crate) struct Builder {
    pub g: Graph,
    seed: u64,
}

impl Builder {
    pub fn new(seed: u64) -> (Builder, NodeId) {
        let mut g = Graph::new();
        let input = g.input();
        (Builder { g, seed }, input)
    }

    fn next_seed(&mut self) -> u64 {
        self.seed = self.seed.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
        self.seed
    }

    /// Conv + bias + ReLU.
    pub fn conv(
        &mut self,
        name: &str,
        from: NodeId,
        cin: usize,
        cout: usize,
        kernel: (usize, usize),
        stride: (usize, usize),
        pad: (usize, usize),
    ) -> NodeId {
        self.conv_act(name, from, cin, cout, kernel, stride, pad, Activation::Relu)
    }

    /// Conv + bias + explicit activation (the MobileNets fuse ReLU6, and
    /// MobileNetV2's projection layers are linear).
    #[allow(clippy::too_many_arguments)]
    pub fn conv_act(
        &mut self,
        name: &str,
        from: NodeId,
        cin: usize,
        cout: usize,
        kernel: (usize, usize),
        stride: (usize, usize),
        pad: (usize, usize),
        act: Activation,
    ) -> NodeId {
        let desc = Conv2d::new(cin, cout, kernel)
            .with_stride(stride)
            .with_padding(pad);
        let weights = desc.random_weights(self.next_seed());
        let bias_seed = self.next_seed();
        let bias = Tensor::rand_uniform(&[cout], -0.05, 0.05, bias_seed).into_vec();
        self.g.add(
            name,
            Op::Conv { desc, weights, bias, act },
            &[from],
        )
    }

    /// Depthwise 3×3 conv (`groups == cin == cout`) + bias + activation —
    /// same-padded, stride 1 or 2, `[C, 3, 3, 1]` weights.
    pub fn dwconv(
        &mut self,
        name: &str,
        from: NodeId,
        c: usize,
        stride: usize,
        act: Activation,
    ) -> NodeId {
        let desc = Conv2d::new(c, c, (3, 3))
            .with_groups(c)
            .with_stride((stride, stride))
            .with_padding((1, 1));
        let weights = desc.random_weights(self.next_seed());
        let bias_seed = self.next_seed();
        let bias = Tensor::rand_uniform(&[c], -0.05, 0.05, bias_seed).into_vec();
        self.g.add(
            name,
            Op::Conv { desc, weights, bias, act },
            &[from],
        )
    }

    /// Elementwise residual add. Keep the conv operand FIRST and the skip
    /// connection second: the prepared-model fusion matcher is
    /// order-agnostic, but conv-first is the convention every zoo residual
    /// uses (`Conv(1×1) → Add → Act` reads in graph order).
    pub fn add(&mut self, name: &str, a: NodeId, b: NodeId) -> NodeId {
        self.g.add(name, Op::Add, &[a, b])
    }

    /// Standalone post-add ReLU (the ResNet block tail; fuses into the
    /// pointwise residual GEMM when the preceding Add qualifies).
    pub fn relu(&mut self, name: &str, from: NodeId) -> NodeId {
        self.g.add(name, Op::Relu, &[from])
    }

    pub fn maxpool(
        &mut self,
        name: &str,
        from: NodeId,
        k: usize,
        s: usize,
        pad: usize,
        ceil: bool,
    ) -> NodeId {
        self.g.add(
            name,
            Op::MaxPool { kernel: (k, k), stride: (s, s), pad: (pad, pad), ceil },
            &[from],
        )
    }

    pub fn avgpool(
        &mut self,
        name: &str,
        from: NodeId,
        k: usize,
        s: usize,
        pad: usize,
    ) -> NodeId {
        self.g.add(
            name,
            Op::AvgPool { kernel: (k, k), stride: (s, s), pad: (pad, pad), ceil: false },
            &[from],
        )
    }

    pub fn gap(&mut self, name: &str, from: NodeId) -> NodeId {
        self.g.add(name, Op::GlobalAvgPool, &[from])
    }

    pub fn concat(&mut self, name: &str, from: &[NodeId]) -> NodeId {
        self.g.add(name, Op::Concat, from)
    }

    pub fn fc(&mut self, name: &str, from: NodeId, k: usize, m: usize, relu: bool) -> NodeId {
        let w_seed = self.next_seed();
        let scale = (2.0 / k as f32).sqrt();
        let mut weights = Tensor::randn(&[k, m], w_seed);
        for v in weights.data_mut() {
            *v *= scale;
        }
        self.g.add(
            name,
            Op::Fc { weights, bias: vec![0.0; m], relu },
            &[from],
        )
    }

    pub fn softmax(&mut self, name: &str, from: NodeId) -> NodeId {
        self.g.add(name, Op::Softmax, &[from])
    }

    pub fn lrn(&mut self, name: &str, from: NodeId) -> NodeId {
        self.g.add(
            name,
            Op::Lrn { size: 5, alpha: 1e-4, beta: 0.75, k: 1.0 },
            &[from],
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_roundtrip() {
        for kind in ModelKind::ALL {
            assert_eq!(ModelKind::parse(kind.name()), Some(kind));
        }
        assert_eq!(ModelKind::parse("resnet"), None);
    }

    #[test]
    fn input_shapes() {
        assert_eq!(ModelKind::Vgg16.input_shape(1), vec![1, 224, 224, 3]);
        assert_eq!(ModelKind::InceptionV3.input_shape(2), vec![2, 299, 299, 3]);
    }

    #[test]
    fn all_models_build_and_infer_shapes() {
        for kind in ModelKind::ALL {
            let g = kind.build(1).unwrap();
            let shapes = g.infer_shapes(&kind.input_shape(1)).unwrap();
            // Every model ends in a 1000-way classifier.
            assert_eq!(shapes.last().unwrap(), &vec![1, 1000], "{kind}");
            assert!(g.conv_count() > 5, "{kind} suspiciously small");
        }
    }

    #[test]
    fn weights_are_deterministic_per_seed() {
        let a = ModelKind::SqueezeNet.build(1).unwrap();
        let b = ModelKind::SqueezeNet.build(1).unwrap();
        let (wa, wb) = match (&a.nodes[1].op, &b.nodes[1].op) {
            (Op::Conv { weights: wa, .. }, Op::Conv { weights: wb, .. }) => (wa, wb),
            _ => panic!("node 1 should be a conv"),
        };
        assert_eq!(wa, wb);
    }
}
