//! ResNet-18 and ResNet-50 (He et al. 2015, v1.5 stride placement) — the
//! residual workload class the zero-copy pointwise engine
//! ([`crate::conv::pointwise`]) and its fused residual epilogue exist for.
//!
//! ResNet-18 stacks **basic** blocks (3×3 + 3×3, identity or 1×1/s2
//! projection shortcut); its dense stride-1 3×3 bodies are the classic
//! Winograd territory, while the three downsample projections exercise the
//! pointwise engine's strided gather path. ResNet-50 stacks **bottleneck**
//! blocks (1×1 reduce → 3×3 → 1×1 expand) — over two thirds of its convs
//! are dense 1×1s, and every block ends in the exact
//! `Conv(1×1, linear) → Add → Relu` chain the prepared model collapses
//! into one fused-residual pointwise GEMM.
//!
//! Residual adds follow the zoo convention: conv operand first, skip
//! connection second. Block tails are standalone [`crate::nn::Op::Relu`]
//! nodes so the fusion matcher sees the post-add activation explicitly.

use super::Builder;
use crate::conv::Activation;
use crate::nn::{Graph, NodeId};
use crate::Result;

/// The shared 224×224 stem: 7×7/2 pad-3 conv to 64 channels (ReLU), then
/// 3×3/2 pad-1 max-pool — 224 → 112 → 56.
fn stem(b: &mut Builder, input: NodeId) -> NodeId {
    let c1 = b.conv_act("conv1", input, 3, 64, (7, 7), (2, 2), (3, 3), Activation::Relu);
    b.maxpool("pool1", c1, 3, 2, 1, false)
}

/// The shortcut operand: identity when the block keeps shape, else a
/// linear 1×1 projection matching channels (and stride, on downsample
/// blocks).
fn shortcut(
    b: &mut Builder,
    name: &str,
    from: NodeId,
    cin: usize,
    cout: usize,
    stride: usize,
) -> NodeId {
    if stride == 1 && cin == cout {
        from
    } else {
        b.conv_act(
            &format!("{name}/proj"),
            from,
            cin,
            cout,
            (1, 1),
            (stride, stride),
            (0, 0),
            Activation::None,
        )
    }
}

/// ResNet-18/34 basic block: 3×3 (stride `s`, ReLU) → 3×3 (linear) →
/// add shortcut → ReLU.
fn basic_block(
    b: &mut Builder,
    name: &str,
    from: NodeId,
    cin: usize,
    cout: usize,
    stride: usize,
) -> NodeId {
    let c1 = b.conv_act(
        &format!("{name}/conv1"),
        from,
        cin,
        cout,
        (3, 3),
        (stride, stride),
        (1, 1),
        Activation::Relu,
    );
    let c2 = b.conv_act(
        &format!("{name}/conv2"),
        c1,
        cout,
        cout,
        (3, 3),
        (1, 1),
        (1, 1),
        Activation::None,
    );
    let sc = shortcut(b, name, from, cin, cout, stride);
    let add = b.add(&format!("{name}/add"), c2, sc);
    b.relu(&format!("{name}/relu"), add)
}

/// ResNet-50 bottleneck: 1×1 reduce (ReLU) → 3×3 (stride `s`, ReLU) →
/// 1×1 expand (linear) → add shortcut → ReLU. The expand → add → relu
/// tail is the fused pointwise-residual chain.
fn bottleneck(
    b: &mut Builder,
    name: &str,
    from: NodeId,
    cin: usize,
    width: usize,
    cout: usize,
    stride: usize,
) -> NodeId {
    let reduce = b.conv_act(
        &format!("{name}/reduce"),
        from,
        cin,
        width,
        (1, 1),
        (1, 1),
        (0, 0),
        Activation::Relu,
    );
    let mid = b.conv_act(
        &format!("{name}/conv3x3"),
        reduce,
        width,
        width,
        (3, 3),
        (stride, stride),
        (1, 1),
        Activation::Relu,
    );
    let expand = b.conv_act(
        &format!("{name}/expand"),
        mid,
        width,
        cout,
        (1, 1),
        (1, 1),
        (0, 0),
        Activation::None,
    );
    let sc = shortcut(b, name, from, cin, cout, stride);
    let add = b.add(&format!("{name}/add"), expand, sc);
    b.relu(&format!("{name}/relu"), add)
}

/// Build ResNet-18 (224×224×3 → 1000 classes): stem, four stages of two
/// basic blocks at widths 64/128/256/512 (stages 2–4 downsample), GAP, FC.
pub fn build_18(seed: u64) -> Result<Graph> {
    let (mut b, input) = Builder::new(seed);
    let mut prev = stem(&mut b, input);
    let mut cin = 64;
    // (stage width, first-block stride) — 56 → 56 → 28 → 14 → 7.
    let stages: [(usize, usize); 4] = [(64, 1), (128, 2), (256, 2), (512, 2)];
    for (si, &(w, s)) in stages.iter().enumerate() {
        for rep in 0..2 {
            let stride = if rep == 0 { s } else { 1 };
            prev = basic_block(
                &mut b,
                &format!("stage{}/block{}", si + 1, rep + 1),
                prev,
                cin,
                w,
                stride,
            );
            cin = w;
        }
    }
    let gap = b.gap("gap", prev);
    let fc = b.fc("fc", gap, 512, 1000, false);
    b.softmax("prob", fc);
    Ok(b.g)
}

/// Build ResNet-50 (224×224×3 → 1000 classes): stem, bottleneck stages
/// [3, 4, 6, 3] at widths 64/128/256/512 with 4× expansion, GAP, FC.
pub fn build_50(seed: u64) -> Result<Graph> {
    let (mut b, input) = Builder::new(seed);
    let mut prev = stem(&mut b, input);
    let mut cin = 64;
    // (bottleneck width, output channels, repeats, first-block stride).
    let stages: [(usize, usize, usize, usize); 4] = [
        (64, 256, 3, 1),
        (128, 512, 4, 2),
        (256, 1024, 6, 2),
        (512, 2048, 3, 2),
    ];
    for (si, &(w, cout, n, s)) in stages.iter().enumerate() {
        for rep in 0..n {
            let stride = if rep == 0 { s } else { 1 };
            prev = bottleneck(
                &mut b,
                &format!("stage{}/block{}", si + 1, rep + 1),
                prev,
                cin,
                w,
                cout,
                stride,
            );
            cin = cout;
        }
    }
    let gap = b.gap("gap", prev);
    let fc = b.fc("fc", gap, 2048, 1000, false);
    b.softmax("prob", fc);
    Ok(b.g)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::{Op, PreparedModel, Scheme};

    #[test]
    fn r18_structure() {
        let g = build_18(1).unwrap();
        // Stem + 8 × (two 3×3) + 3 downsample projections = 20 convs.
        assert_eq!(g.conv_count(), 20);
        let shapes = g.infer_shapes(&[1, 224, 224, 3]).unwrap();
        assert_eq!(shapes.last().unwrap(), &vec![1, 1000]);
        let adds = g.nodes.iter().filter(|n| matches!(n.op, Op::Add)).count();
        let relus = g.nodes.iter().filter(|n| matches!(n.op, Op::Relu)).count();
        assert_eq!(adds, 8);
        assert_eq!(relus, 8);
        // Spatial schedule 56 → 28 → 14 → 7 at widths 64/128/256/512.
        let idx = |name: &str| g.nodes.iter().position(|n| n.name == name).unwrap();
        assert_eq!(shapes[idx("pool1")], vec![1, 56, 56, 64]);
        assert_eq!(shapes[idx("stage2/block1/relu")], vec![1, 28, 28, 128]);
        assert_eq!(shapes[idx("stage4/block2/relu")], vec![1, 7, 7, 512]);
        // Exactly the three downsample projections are 1×1.
        let pw = g
            .nodes
            .iter()
            .filter(|n| matches!(&n.op, Op::Conv { desc, .. } if desc.kernel == (1, 1)))
            .count();
        assert_eq!(pw, 3);
    }

    #[test]
    fn r50_structure() {
        let g = build_50(1).unwrap();
        // Stem + 16 × (reduce, 3×3, expand) + 4 projections = 53 convs.
        assert_eq!(g.conv_count(), 53);
        let shapes = g.infer_shapes(&[1, 224, 224, 3]).unwrap();
        assert_eq!(shapes.last().unwrap(), &vec![1, 1000]);
        let adds = g.nodes.iter().filter(|n| matches!(n.op, Op::Add)).count();
        assert_eq!(adds, 16);
        // Two thirds of the convs are dense 1×1 pointwise layers.
        let pw = g
            .nodes
            .iter()
            .filter(|n| matches!(&n.op, Op::Conv { desc, .. } if desc.kernel == (1, 1)))
            .count();
        assert_eq!(pw, 36);
        let idx = |name: &str| g.nodes.iter().position(|n| n.name == name).unwrap();
        assert_eq!(shapes[idx("stage1/block1/relu")], vec![1, 56, 56, 256]);
        assert_eq!(shapes[idx("stage4/block3/relu")], vec![1, 7, 7, 2048]);
    }

    /// Every dense 1×1 binds to the pointwise engine on the ours scheme,
    /// and every bottleneck tail fuses: the census counts all 36 ResNet-50
    /// pointwise layers (16 of them as fused-residual tails) and the three
    /// ResNet-18 strided projections.
    #[test]
    fn prepared_census_routes_pointwise() {
        let g18 = build_18(7).unwrap();
        let m18 =
            PreparedModel::prepare("r18", &g18, &[1, 224, 224, 3], Scheme::WinogradWhereSuitable)
                .unwrap();
        assert_eq!(m18.dispatch_census().pointwise, 3);
        // The eight stride-1 block bodies are Winograd-suitable.
        assert!(m18.dispatch_census().winograd > 0);

        let g50 = build_50(7).unwrap();
        let m50 =
            PreparedModel::prepare("r50", &g50, &[1, 224, 224, 3], Scheme::WinogradWhereSuitable)
                .unwrap();
        assert_eq!(m50.dispatch_census().pointwise, 36);
        // Baseline scheme: the same 1×1s stay on im2row, bit-identically.
        let b50 = PreparedModel::prepare("r50", &g50, &[1, 224, 224, 3], Scheme::Im2RowOnly)
            .unwrap();
        assert_eq!(b50.dispatch_census().pointwise, 0);
        assert_eq!(
            b50.dispatch_census().total(),
            m50.dispatch_census().total(),
            "fusion must not drop conv layers from the census"
        );
    }
}
