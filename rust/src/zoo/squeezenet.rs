//! SqueezeNet v1.0 (Iandola et al. 2016).
//!
//! Fire modules: a 1×1 *squeeze* conv followed by parallel 1×1 and 3×3
//! *expand* convs, concatenated. Only the 3×3 expand halves are
//! Winograd-suitable — which is why SqueezeNet shows the paper's smallest
//! whole-network gain (29.6%, Table 1) despite a 53% fast-layer gain; the
//! paper still reports 47 frames/sec for it on 4× Cortex-A73 (§1).

use super::Builder;
use crate::nn::{Graph, NodeId};
use crate::Result;

/// One fire module; returns the concat node.
fn fire(
    b: &mut Builder,
    name: &str,
    from: NodeId,
    cin: usize,
    squeeze: usize,
    expand1: usize,
    expand3: usize,
) -> NodeId {
    let s = b.conv(&format!("{name}/squeeze1x1"), from, cin, squeeze, (1, 1), (1, 1), (0, 0));
    let e1 = b.conv(&format!("{name}/expand1x1"), s, squeeze, expand1, (1, 1), (1, 1), (0, 0));
    let e3 = b.conv(&format!("{name}/expand3x3"), s, squeeze, expand3, (3, 3), (1, 1), (1, 1));
    b.concat(&format!("{name}/concat"), &[e1, e3])
}

/// Build SqueezeNet v1.0 (224×224×3 → 1000 classes).
pub fn build(seed: u64) -> Result<Graph> {
    let (mut b, input) = Builder::new(seed);
    // conv1: 7×7/2, 96 filters (v1.0).
    let c1 = b.conv("conv1", input, 3, 96, (7, 7), (2, 2), (3, 3));
    let p1 = b.maxpool("pool1", c1, 3, 2, 0, true); // 109→55 ceil ⇒ 27? see infer
    let f2 = fire(&mut b, "fire2", p1, 96, 16, 64, 64);
    let f3 = fire(&mut b, "fire3", f2, 128, 16, 64, 64);
    let f4 = fire(&mut b, "fire4", f3, 128, 32, 128, 128);
    let p4 = b.maxpool("pool4", f4, 3, 2, 0, true);
    let f5 = fire(&mut b, "fire5", p4, 256, 32, 128, 128);
    let f6 = fire(&mut b, "fire6", f5, 256, 48, 192, 192);
    let f7 = fire(&mut b, "fire7", f6, 384, 48, 192, 192);
    let f8 = fire(&mut b, "fire8", f7, 384, 64, 256, 256);
    let p8 = b.maxpool("pool8", f8, 3, 2, 0, true);
    let f9 = fire(&mut b, "fire9", p8, 512, 64, 256, 256);
    // conv10: 1×1 to 1000 classes, then global average pool.
    let c10 = b.conv("conv10", f9, 512, 1000, (1, 1), (1, 1), (0, 0));
    let gap = b.gap("pool10", c10);
    let flat = b.fc("flatten", gap, 1000, 1000, false);
    b.softmax("prob", flat);
    Ok(b.g)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::Op;

    #[test]
    fn structure() {
        let g = build(1).unwrap();
        // conv1 + 8 fires × 3 convs + conv10 = 26 convs.
        assert_eq!(g.conv_count(), 26);
        let shapes = g.infer_shapes(&[1, 224, 224, 3]).unwrap();
        assert_eq!(shapes.last().unwrap(), &vec![1, 1000]);
    }

    #[test]
    fn fire_concat_widths() {
        let g = build(1).unwrap();
        let shapes = g.infer_shapes(&[1, 224, 224, 3]).unwrap();
        let idx = g.nodes.iter().position(|n| n.name == "fire9/concat").unwrap();
        assert_eq!(shapes[idx][3], 512);
    }

    #[test]
    fn only_expand3x3_is_wino_suitable() {
        let g = build(1).unwrap();
        for n in &g.nodes {
            if let Op::Conv { desc, .. } = &n.op {
                let suitable =
                    crate::conv::select::is_winograd_suitable(desc.kernel, desc.stride, desc.groups);
                assert_eq!(
                    suitable,
                    n.name.contains("expand3x3"),
                    "{}: kernel {:?}",
                    n.name,
                    desc.kernel
                );
            }
        }
    }
}
