//! VGG-16 / VGG-19 (Simonyan & Zisserman, configurations D and E).
//!
//! All conv layers are 3×3 stride-1 pad-1 — the paper's best case: nearly
//! the whole network is Winograd-suitable (Table 1 shows a 60.7% whole-
//! network win on VGG-16).

use super::Builder;
use crate::nn::Graph;
use crate::Result;

/// Build VGG-16 (`depth = 16`) or VGG-19 (`depth = 19`).
pub fn build(depth: usize, seed: u64) -> Result<Graph> {
    assert!(depth == 16 || depth == 19, "VGG depth must be 16 or 19");
    // Convs per block: VGG-16 = [2,2,3,3,3], VGG-19 = [2,2,4,4,4].
    let per_block: [usize; 5] = if depth == 16 { [2, 2, 3, 3, 3] } else { [2, 2, 4, 4, 4] };
    let widths = [64usize, 128, 256, 512, 512];

    let (mut b, input) = Builder::new(seed);
    let mut x = input;
    let mut cin = 3usize;
    for (bi, (&n_convs, &width)) in per_block.iter().zip(&widths).enumerate() {
        for li in 0..n_convs {
            let name = format!("conv{}_{}", bi + 1, li + 1);
            x = b.conv(&name, x, cin, width, (3, 3), (1, 1), (1, 1));
            cin = width;
        }
        x = b.maxpool(&format!("pool{}", bi + 1), x, 2, 2, 0, false);
    }
    // 224/2^5 = 7 ⇒ 7·7·512 = 25088 features.
    x = b.fc("fc6", x, 7 * 7 * 512, 4096, true);
    x = b.fc("fc7", x, 4096, 4096, true);
    x = b.fc("fc8", x, 4096, 1000, false);
    b.softmax("prob", x);
    Ok(b.g)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::Op;

    #[test]
    fn vgg16_structure() {
        let g = build(16, 1).unwrap();
        assert_eq!(g.conv_count(), 13);
        let shapes = g.infer_shapes(&[1, 224, 224, 3]).unwrap();
        assert_eq!(shapes.last().unwrap(), &vec![1, 1000]);
        // conv5_3 output is 14×14×512 before the final pool.
        let idx = g.nodes.iter().position(|n| n.name == "conv5_3").unwrap();
        assert_eq!(shapes[idx], vec![1, 14, 14, 512]);
    }

    #[test]
    fn vgg19_has_16_convs() {
        let g = build(19, 1).unwrap();
        assert_eq!(g.conv_count(), 16);
    }

    #[test]
    fn all_convs_are_3x3_stride1() {
        let g = build(16, 1).unwrap();
        for n in &g.nodes {
            if let Op::Conv { desc, .. } = &n.op {
                assert_eq!(desc.kernel, (3, 3));
                assert_eq!(desc.stride, (1, 1));
                assert_eq!(desc.padding, (1, 1));
            }
        }
    }
}
