//! Cross-module integration tests: model zoo → prepared executor →
//! coordinator, algorithm-equivalence matrices, and property-based checks
//! over the full convolution stack (the crate's own `testkit` substitutes
//! for proptest in this offline build).

use winoconv::conv::direct::direct_conv2d;
use winoconv::conv::{Conv2d, ConvAlgorithm};
use winoconv::coordinator::{EngineConfig, InferenceEngine};
use winoconv::im2row::im2row_conv2d;
use winoconv::nn::{PreparedModel, Scheme};
use winoconv::parallel::ThreadPool;
use winoconv::quant::Dtype;
use winoconv::tensor::Tensor;
use winoconv::testkit::{check, Gen};
use winoconv::winograd::{winograd_conv2d, WinogradConvolution, WinogradVariant};
use winoconv::workspace::Workspace;
use winoconv::zoo::ModelKind;

/// Property: for any geometry a variant accepts, the region-wise pipeline
/// equals direct convolution.
#[test]
fn property_winograd_equals_direct() {
    check("winograd == direct over random geometry", 40, |g: &mut Gen| {
        let variants = [
            WinogradVariant::F2x2_3x3,
            WinogradVariant::F4x4_3x3,
            WinogradVariant::F2x2_5x5,
            WinogradVariant::F4_1x3,
            WinogradVariant::F2_7x1,
        ];
        let v = *g.choose(&variants);
        let (kh, kw) = v.kernel();
        let h = g.usize_in(kh, kh + 12);
        let w = g.usize_in(kw, kw + 12);
        let c = g.usize_in(1, 8);
        let m = g.usize_in(1, 8);
        let n = g.usize_in(1, 2);
        let pad = (g.usize_in(0, kh / 2), g.usize_in(0, kw / 2));
        let input = Tensor::from_vec(&[n, h, w, c], g.normal_vec(n * h * w * c)).unwrap();
        let weights =
            Tensor::from_vec(&[m, kh, kw, c], g.normal_vec(m * kh * kw * c)).unwrap();
        let got = winograd_conv2d(v, &input, &weights, pad, None).unwrap();
        let want = direct_conv2d(&input, &weights, (1, 1), pad).unwrap();
        got.allclose(&want, 2e-3)
    });
}

/// Property: im2row equals direct for arbitrary stride/pad/kernel.
#[test]
fn property_im2row_equals_direct() {
    check("im2row == direct over random geometry", 40, |g: &mut Gen| {
        let kh = g.usize_in(1, 5);
        let kw = g.usize_in(1, 5);
        let sh = g.usize_in(1, 3);
        let sw = g.usize_in(1, 3);
        let h = g.usize_in(kh, kh + 10);
        let w = g.usize_in(kw, kw + 10);
        let c = g.usize_in(1, 6);
        let m = g.usize_in(1, 6);
        let pad = (g.usize_in(0, 2), g.usize_in(0, 2));
        let input = Tensor::from_vec(&[1, h, w, c], g.normal_vec(h * w * c)).unwrap();
        let weights =
            Tensor::from_vec(&[m, kh, kw, c], g.normal_vec(m * kh * kw * c)).unwrap();
        let got = im2row_conv2d(&input, &weights, (sh, sw), pad, None).unwrap();
        let want = direct_conv2d(&input, &weights, (sh, sw), pad).unwrap();
        got.allclose(&want, 1e-3)
    });
}

/// The two whole-network schemes agree numerically on a real model.
#[test]
fn squeezenet_schemes_agree() {
    let model = ModelKind::SqueezeNet;
    let graph = model.build(5).unwrap();
    let shape = model.input_shape(1);
    let input = Tensor::randn(&shape, 17);
    let pool = ThreadPool::new(2);
    let base = PreparedModel::prepare("sq", &graph, &shape, Scheme::Im2RowOnly).unwrap();
    let ours = PreparedModel::prepare("sq", &graph, &shape, Scheme::WinogradWhereSuitable).unwrap();
    let (y1, t1) = base.run(&input, Some(&pool)).unwrap();
    let (y2, t2) = ours.run(&input, Some(&pool)).unwrap();
    assert_eq!(y1.shape(), &[1, 1000]);
    assert!(y2.allclose(&y1, 5e-3), "schemes diverge");
    // The "ours" run must actually have bound Winograd layers.
    assert!(t2.iter().filter(|t| t.winograd).count() >= 8);
    assert!(t1.iter().all(|t| !t.winograd));
    // Softmax output is a distribution either way.
    let s: f32 = y2.data().iter().sum();
    assert!((s - 1.0).abs() < 1e-3);
    // The pre-sized arenas must not have grown during inference, and the
    // single-consumer runs must never have taken the allocating fallback.
    assert_eq!(base.workspace_stats().1, 0, "im2row arena regrew");
    assert_eq!(ours.workspace_stats().1, 0, "winograd arena regrew");
    assert_eq!(base.fallback_count() + ours.fallback_count(), 0);
}

/// The fully planned write-into path on a real model: explicit pre-sized
/// arena pair, caller-provided output slice, bit-identical to `run`, zero
/// arena growth and zero fallbacks — the end-to-end
/// "steady-state inference performs no heap allocation" guarantee.
#[test]
fn squeezenet_planned_path_is_allocation_free() {
    let model = ModelKind::SqueezeNet;
    let graph = model.build(5).unwrap();
    let shape = model.input_shape(1);
    let input = Tensor::randn(&shape, 41);
    let pool = ThreadPool::new(2);
    let prepared =
        PreparedModel::prepare("sq", &graph, &shape, Scheme::WinogradWhereSuitable).unwrap();
    let plan = prepared.activation_plan();
    assert!(
        plan.peak_elems() < plan.naive_elems(),
        "planner must beat per-layer allocation on SqueezeNet"
    );
    let (want, _) = prepared.run(&input, Some(&pool)).unwrap();
    let mut ws = Workspace::with_capacity(prepared.workspace_elems());
    let mut acts = Workspace::with_capacity(plan.peak_elems());
    let mut out = vec![f32::NAN; want.len()];
    for _ in 0..2 {
        prepared
            .run_planned_into(&input, Some(&pool), &mut ws, &mut acts, &mut out)
            .unwrap();
        assert_eq!(out, want.data(), "planned-into output differs from run()");
    }
    assert_eq!(ws.grow_count(), 0, "scratch arena grew after pre-sizing");
    assert_eq!(acts.grow_count(), 0, "activation arena grew after pre-sizing");
    assert_eq!(prepared.fallback_count(), 0, "no contention, no fallback");
}

/// MobileNetV1 and MobileNetV2 end-to-end through the planned write-into
/// path (the acceptance gate): every 3×3 depthwise layer dispatches to the
/// direct depthwise engine, `run_planned_into` matches `run()` bit for
/// bit on a NaN-poisoned output slice, and grow-count = fallback-count = 0
/// over pre-sized arenas.
#[test]
fn mobilenets_planned_path_is_allocation_free() {
    let pool = ThreadPool::new(2);
    for model in [ModelKind::MobileNetV1, ModelKind::MobileNetV2] {
        let graph = model.build(3).unwrap();
        let shape = model.input_shape(1);
        let input = Tensor::randn(&shape, 19);
        let prepared =
            PreparedModel::prepare(model.name(), &graph, &shape, Scheme::WinogradWhereSuitable)
                .unwrap();
        // Binding census: all depthwise layers on the direct engine, the
        // pointwise/stem layers on im2row, nothing on Winograd (no
        // suitable layer exists in either MobileNet).
        let census = prepared.dispatch_census();
        let expect_dw = if model == ModelKind::MobileNetV1 { 13 } else { 17 };
        assert_eq!(census.depthwise, expect_dw, "{model}");
        assert_eq!(census.winograd, 0, "{model}");
        assert_eq!(census.direct, 0, "{model}");
        assert!(census.im2row > 0, "{model}");

        let plan = prepared.activation_plan();
        assert!(plan.peak_elems() < plan.naive_elems(), "{model}: planner found no sharing");
        let (want, timings) = prepared.run(&input, Some(&pool)).unwrap();
        assert_eq!(want.shape(), &[1, 1000]);
        let s: f32 = want.data().iter().sum();
        assert!((s - 1.0).abs() < 1e-3, "{model}: softmax distribution");
        assert!(timings.iter().all(|t| !t.winograd), "{model}");

        let mut ws = Workspace::with_capacity(prepared.workspace_elems());
        let mut acts = Workspace::with_capacity(plan.peak_elems());
        let mut out = vec![f32::NAN; want.len()];
        for _ in 0..2 {
            prepared
                .run_planned_into(&input, Some(&pool), &mut ws, &mut acts, &mut out)
                .unwrap();
            assert_eq!(out, want.data(), "{model}: planned-into differs from run()");
        }
        assert_eq!(ws.grow_count(), 0, "{model}: scratch arena grew");
        assert_eq!(acts.grow_count(), 0, "{model}: activation arena grew");
        assert_eq!(prepared.fallback_count(), 0, "{model}: fallback taken");
        // 3 completed walks × the static census.
        let counts = prepared.dispatch_counts();
        assert_eq!(counts.depthwise, 3 * expect_dw, "{model}");
        assert_eq!(counts.total(), 3 * census.total(), "{model}");

        // Both schemes bind MobileNets identically (no Winograd-suitable
        // layer), so their outputs are bit-identical.
        let base =
            PreparedModel::prepare(model.name(), &graph, &shape, Scheme::Im2RowOnly).unwrap();
        let (y_base, _) = base.run(&input, Some(&pool)).unwrap();
        assert_eq!(y_base.data(), want.data(), "{model}: schemes must bind identically");
    }
}

/// Quantized MobileNetV1 end-to-end: every conv binds an int8 engine with
/// an exact dispatch census (13 depthwise + 13 pointwise + the dense
/// stem), the planned write-into path is allocation-free and bit-identical
/// to `run()`, both schemes bind int8 identically, and the output stays a
/// valid softmax distribution within the drift budget of the f32 oracle.
#[test]
fn quantized_mobilenet_planned_path_is_allocation_free() {
    let pool = ThreadPool::new(2);
    let model = ModelKind::MobileNetV1;
    assert!(model.quantizable());
    let graph = model.build(3).unwrap();
    let shape = model.input_shape(1);
    let input = Tensor::randn(&shape, 19);
    let prepared = PreparedModel::prepare_with_dtype(
        model.name(),
        &graph,
        &shape,
        Scheme::WinogradWhereSuitable,
        Dtype::Int8,
    )
    .unwrap();
    let census = prepared.dispatch_census();
    assert_eq!(census.depthwise_i8, 13);
    assert_eq!(census.pointwise_i8, 13);
    assert_eq!(census.im2row_i8, 1, "the stem 3x3/s2 is the only dense spatial conv");
    assert_eq!(census.total(), 27, "every conv dispatches through an int8 lane");

    let (want, timings) = prepared.run(&input, Some(&pool)).unwrap();
    assert_eq!(want.shape(), &[1, 1000]);
    let s: f32 = want.data().iter().sum();
    assert!((s - 1.0).abs() < 1e-3, "softmax distribution");
    assert!(timings.iter().all(|t| !t.winograd));

    let plan = prepared.activation_plan();
    assert!(plan.peak_elems() < plan.naive_elems(), "planner found no sharing");
    let mut ws = Workspace::with_capacity(prepared.workspace_elems());
    let mut acts = Workspace::with_capacity(plan.peak_elems());
    let mut out = vec![f32::NAN; want.len()];
    for _ in 0..2 {
        prepared
            .run_planned_into(&input, Some(&pool), &mut ws, &mut acts, &mut out)
            .unwrap();
        assert_eq!(out, want.data(), "planned-into differs from run()");
    }
    assert_eq!(ws.grow_count(), 0, "scratch arena grew");
    assert_eq!(acts.grow_count(), 0, "activation arena grew");
    assert_eq!(prepared.fallback_count(), 0, "fallback taken");
    // 3 completed walks × the static census, all in the int8 lanes.
    let counts = prepared.dispatch_counts();
    assert_eq!(counts.depthwise_i8, 3 * 13);
    assert_eq!(counts.pointwise_i8, 3 * 13);
    assert_eq!(counts.im2row_i8, 3);
    assert_eq!(counts.total(), 3 * census.total());

    // Int8 binds identically on both schemes → bit-identical outputs.
    let base = PreparedModel::prepare_with_dtype(
        model.name(),
        &graph,
        &shape,
        Scheme::Im2RowOnly,
        Dtype::Int8,
    )
    .unwrap();
    let (y_base, _) = base.run(&input, Some(&pool)).unwrap();
    assert_eq!(y_base.data(), want.data(), "schemes must bind int8 identically");

    // Whole-network drift vs the f32 oracle stays inside the calibrated
    // budget (see the table1 smoke gate for the derivation of 0.25).
    let f32_m = PreparedModel::prepare(model.name(), &graph, &shape, Scheme::Im2RowOnly).unwrap();
    let (oracle, _) = f32_m.run(&input, Some(&pool)).unwrap();
    let peak = oracle.data().iter().fold(0f32, |a, &v| a.max(v.abs())).max(1e-12);
    let drift = want
        .data()
        .iter()
        .zip(oracle.data())
        .fold(0f32, |a, (&x, &y)| a.max((x - y).abs()));
    assert!(drift <= 0.25 * peak, "int8 drift {drift} vs f32 peak {peak}");
}

/// GoogleNet end-to-end through branches/concats/LRN under the Winograd
/// scheme, checked against the im2row scheme.
#[test]
fn googlenet_schemes_agree() {
    let model = ModelKind::GoogleNet;
    let graph = model.build(6).unwrap();
    let shape = model.input_shape(1);
    let input = Tensor::randn(&shape, 8);
    let pool = ThreadPool::new(2);
    let base = PreparedModel::prepare("gn", &graph, &shape, Scheme::Im2RowOnly).unwrap();
    let ours = PreparedModel::prepare("gn", &graph, &shape, Scheme::WinogradWhereSuitable).unwrap();
    let (y1, _) = base.run(&input, Some(&pool)).unwrap();
    let (y2, _) = ours.run(&input, Some(&pool)).unwrap();
    assert!(y2.allclose(&y1, 5e-3));
}

/// Coordinator end-to-end: many concurrent clients on a real (small) model.
#[test]
fn engine_serves_squeezenet_concurrently() {
    let model = ModelKind::SqueezeNet;
    let graph = model.build(9).unwrap();
    let shape = model.input_shape(1);
    let prepared =
        PreparedModel::prepare("sq", &graph, &shape, Scheme::WinogradWhereSuitable).unwrap();
    let engine = std::sync::Arc::new(InferenceEngine::start(
        prepared,
        EngineConfig {
            threads: 2,
            queue_capacity: 8,
            ..EngineConfig::default()
        },
    ));
    let handles: Vec<_> = (0..3)
        .map(|cid| {
            let engine = std::sync::Arc::clone(&engine);
            let shape = shape.clone();
            std::thread::spawn(move || {
                for i in 0..2 {
                    let input = Tensor::randn(&shape, cid * 100 + i);
                    let resp = engine.infer(input).unwrap();
                    assert_eq!(resp.output.shape(), &[1, 1000]);
                }
            })
        })
        .collect();
    for h in handles {
        h.join().unwrap();
    }
    let m = engine.metrics();
    assert_eq!(m.completed, 6);
    assert!(m.throughput_fps > 0.0);
    // The engine's per-worker-arena path: no run() fallbacks, no growth.
    assert_eq!(m.arena_fallbacks, 0);
    assert_eq!(m.arena_grows, 0);
}

/// Every algorithm the public API exposes computes the same 3×3 layer.
#[test]
fn conv2d_algorithm_matrix() {
    let conv = Conv2d::new(8, 16, (3, 3)).with_padding((1, 1));
    let x = Tensor::randn(&[2, 12, 12, 8], 1);
    let w = conv.random_weights(2);
    let pool = ThreadPool::new(2);
    let reference = conv
        .clone()
        .with_algorithm(ConvAlgorithm::Direct)
        .run(&x, &w)
        .unwrap();
    for alg in [
        ConvAlgorithm::Im2Row,
        ConvAlgorithm::Winograd(WinogradVariant::F2x2_3x3),
        ConvAlgorithm::Winograd(WinogradVariant::F4x4_3x3),
        ConvAlgorithm::Winograd(WinogradVariant::F6x6_3x3),
        ConvAlgorithm::Auto,
    ] {
        let got = conv
            .clone()
            .with_algorithm(alg)
            .run_with(&x, &w, Some(&pool))
            .unwrap();
        assert!(got.allclose(&reference, 2e-3), "{alg} diverges");
    }
}

/// The public per-layer workspace API: repeated runs over one arena match
/// the allocating path and never re-grow the arena after the first pass.
#[test]
fn conv2d_workspace_api_matches_run() {
    let conv = Conv2d::new(8, 16, (3, 3)).with_padding((1, 1));
    let x = Tensor::randn(&[1, 12, 12, 8], 5);
    let w = conv.random_weights(6);
    let plain = conv.run(&x, &w).unwrap();
    let mut ws = Workspace::new();
    for _ in 0..3 {
        let got = conv.run_with_workspace(&x, &w, None, &mut ws).unwrap();
        assert!(got.allclose(&plain, 1e-6));
    }
    assert_eq!(ws.grow_count(), 1, "arena grows once, then steady state");
}

/// Region blocking is a pure execution-strategy change: a tiny block budget
/// (many blocks) and an unbounded one (single block) agree bit-for-bit-close
/// on a ragged shape, under a pool.
#[test]
fn blocked_execution_equals_unblocked_end_to_end() {
    let pool = ThreadPool::new(2);
    let weights = Tensor::randn(&[24, 3, 3, 12], 8);
    let input = Tensor::randn(&[1, 23, 19, 12], 9);
    let unblocked = WinogradConvolution::new(WinogradVariant::F4x4_3x3, &weights, (1, 1))
        .unwrap()
        .with_block_budget(usize::MAX);
    let blocked = WinogradConvolution::new(WinogradVariant::F4x4_3x3, &weights, (1, 1))
        .unwrap()
        .with_block_budget(8 * 1024);
    let want = unblocked.run(&input, Some(&pool)).unwrap();
    let got = blocked.run(&input, Some(&pool)).unwrap();
    assert!(got.allclose(&want, 1e-5));
    // And both agree with the oracle.
    let direct = direct_conv2d(&input, &weights, (1, 1), (1, 1)).unwrap();
    assert!(got.allclose(&direct, 2e-3));
}

/// Inception-v3's 1-D factorised layers run through the real variants.
#[test]
fn inception_1d_layers_equal_direct() {
    for (v, kh, kw, ph, pw) in [
        (WinogradVariant::F4_1x7, 1usize, 7usize, 0usize, 3usize),
        (WinogradVariant::F4_7x1, 7, 1, 3, 0),
        (WinogradVariant::F4_1x3, 1, 3, 0, 1),
        (WinogradVariant::F4_3x1, 3, 1, 1, 0),
    ] {
        let input = Tensor::randn(&[1, 17, 17, 12], 3);
        let weights = Tensor::randn(&[8, kh, kw, 12], 4);
        let got = winograd_conv2d(v, &input, &weights, (ph, pw), None).unwrap();
        let want = direct_conv2d(&input, &weights, (1, 1), (ph, pw)).unwrap();
        assert!(got.allclose(&want, 2e-3), "{v} diverges from direct");
    }
}
