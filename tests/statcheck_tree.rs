//! Tree-wide gate: the `statcheck` passes must report **zero findings** on
//! the repository at HEAD. A new undocumented `unsafe`, a hot-path
//! allocation, SIMD/entry-point drift, or an unregistered target fails this
//! test (and `ci.sh`, which also runs the binary as its first step).

use winoconv::analysis;

#[test]
fn statcheck_reports_zero_findings_on_the_tree() {
    let root = std::path::Path::new(env!("CARGO_MANIFEST_DIR"));
    let report = analysis::run_all(root).expect("scan the repo tree");
    let rendered: Vec<String> = report.findings.iter().map(|f| f.to_string()).collect();
    assert!(
        report.findings.is_empty(),
        "statcheck findings:\n{}",
        rendered.join("\n")
    );
    // Sanity-pin the counters so an accidentally empty scan cannot pass:
    // the tree has >60 source files and >30 unsafe sites today, and the
    // workspace arena's grow path carries the one expected waiver.
    assert!(report.files_scanned >= 60, "only {} files scanned", report.files_scanned);
    assert!(report.unsafe_sites >= 30, "only {} unsafe sites", report.unsafe_sites);
    assert!(!report.waivers.is_empty(), "expected at least one counted waiver");
}
